"""Distributed step builders: jit'd train / prefill / decode steps with
explicit in/out shardings for a (pod, data, model) mesh.

Each builder returns a :class:`StepBundle` — the jitted function plus
abstract, sharding-annotated arguments — so the multi-pod dry-run can
``bundle.fn.lower(*bundle.args).compile()`` without allocating anything,
and real launchers can feed concrete arrays with the same shardings.

MoE models default to the grouped GShard dispatch with one group per
data-parallel shard (``gshard:<G>``), the scalable formulation whose
dispatch/combine one-hots shard on (group, expert) — see models/moe.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import InputShape, ModelConfig
from ..models import model as model_lib
from ..models import pctx
from ..models import steps as steps_lib
from ..optim import adamw
from .sharding import (axis_size, batch_pspecs, cache_shardings, dp_axes,
                       param_shardings)

# Sharding-invariant RNG: without this, jax.random draws inside a jit with
# sharded out_shardings depend on the output partitioning, so the SAME
# PRNGKey yields DIFFERENT initial weights on different meshes (observed:
# body params diverging ~0.33 abs between a (2,2,2) mesh and single
# device, which then reads as a phantom distributed-numerics bug).
# Deliberately process-global (it is the upcoming JAX default): every
# random draw in this repo must use the partitionable stream, or states
# initialized through different entry points stop agreeing.
jax.config.update("jax_threefry_partitionable", True)

# ---------------------------------------------------------------------------
# Abstract trees
# ---------------------------------------------------------------------------


def abstract_params(cfg: ModelConfig, param_dtype=jnp.float32):
    return jax.eval_shape(
        lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0),
                                      param_dtype))


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: model_lib.init_cache(cfg, batch, max_len))


def abstract_train_state(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                         param_dtype=jnp.float32):
    p = abstract_params(cfg, param_dtype)
    opt = jax.eval_shape(partial(adamw.init, cfg=opt_cfg), p)
    return {"params": p, "opt": opt}


def abstract_batch(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    i32 = jnp.int32
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B,), i32),
                "pos": jax.ShapeDtypeStruct((B,), i32)}
    if cfg.frontend == "audio":
        out["frames"] = jax.ShapeDtypeStruct((B, S, cfg.frontend_dim),
                                             jnp.float32)
    elif cfg.frontend == "vision":
        nf = cfg.n_frontend_tokens
        out["patch_embeds"] = jax.ShapeDtypeStruct((B, nf, cfg.frontend_dim),
                                                   jnp.float32)
        out["tokens"] = jax.ShapeDtypeStruct((B, S - nf), i32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    if shape.kind == "train":
        tgt = (B, S - cfg.n_frontend_tokens) if cfg.frontend == "vision" \
            else (B, S)
        out["targets"] = jax.ShapeDtypeStruct(tgt, i32)
    return out


def _with_shardings(tree, shardings):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings)


def _batch_shardings(cfg, shape, mesh):
    specs = batch_pspecs(cfg, shape, mesh)
    return {k: NamedSharding(mesh, v) for k, v in specs.items()}


# ---------------------------------------------------------------------------
# MoE dispatch / hints
# ---------------------------------------------------------------------------


def _moe_groups(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> int:
    if cfg.moe is None:
        return 1
    dp = dp_axes(mesh)
    G = axis_size(mesh, dp)
    n_tok = shape.global_batch if shape.kind == "decode" \
        else shape.global_batch * shape.seq_len
    if G > 1 and n_tok % G == 0 and shape.global_batch % G == 0:
        return G
    return 1


def _moe_hints(mesh: Mesh, G: int):
    if G <= 1:
        return {}
    dp = dp_axes(mesh)
    dpx = dp if len(dp) > 1 else dp[0]
    return {
        "moe_dispatch": NamedSharding(mesh, P(dpx, None, "model", None)),
        "moe_expert_in": NamedSharding(mesh, P("model", dpx, None, None)),
        "moe_group_buf": NamedSharding(mesh, P(dpx, None, None, None)),
    }


def _dispatch_for(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                  override: Optional[str]) -> Tuple[Optional[str], dict]:
    if cfg.moe is None:
        return None, {}
    if override is not None:
        if override.startswith(("gshard", "sortg")):
            if ":" in override:
                G = int(override.split(":")[1])
            else:
                G = _moe_groups(cfg, shape, mesh)
                override = f"{override}:{G}"
            return override, _moe_hints(mesh, G)
        return override, {}
    G = _moe_groups(cfg, shape, mesh)
    return f"gshard:{G}", _moe_hints(mesh, G)


def _model_hints(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> dict:
    """Activation sharding constraints (models/pctx.py keys): keep the
    batch dim on the DP axes and put heads / FFN-hidden / vocab on "model"
    wherever the dimension divides — GSPMD propagation alone tends to lose
    batch sharding inside scanned attention and replicate (measured: full
    global-batch f32 all-reduces in the backward; see EXPERIMENTS.md)."""
    dp = dp_axes(mesh)
    nm = axis_size(mesh, ("model",))
    dpx = (dp if len(dp) > 1 else dp[0]) if (
        dp and shape.global_batch % axis_size(mesh, dp) == 0) else None
    heads = "model" if cfg.n_heads % nm == 0 else None
    kv = "model" if cfg.n_kv_heads % nm == 0 else None
    if cfg.mla is not None:
        kv = heads
    hints = {
        "activations": NamedSharding(mesh, P(dpx, None, None)),
        "attn_q": NamedSharding(mesh, P(dpx, None, heads, None)),
        "attn_kv": NamedSharding(mesh, P(dpx, None, kv, None)),
    }
    d_ff = cfg.moe.d_ff_dense or cfg.d_ff if cfg.moe else cfg.d_ff
    if d_ff and d_ff % nm == 0:
        hints["ffn_hidden"] = NamedSharding(mesh, P(dpx, None, "model"))
        hints["ffn_hidden_2d"] = NamedSharding(mesh, P(dpx, "model"))
    if cfg.vocab_size % nm == 0:
        hints["logits"] = NamedSharding(mesh, P(dpx, None, "model"))
    return hints


# ---------------------------------------------------------------------------
# Bundles
# ---------------------------------------------------------------------------


@dataclass
class StepBundle:
    name: str
    fn: Callable            # jitted step
    args: tuple             # abstract args (ShapeDtypeStruct trees)
    mesh: Mesh
    meta: dict = field(default_factory=dict)

    def lower(self):
        return self.fn.lower(*self.args)


# -- train ------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                    opt_cfg: Optional[adamw.AdamWConfig] = None,
                    remat: bool = True, microbatch: int = 1,
                    dispatch: Optional[str] = None,
                    param_dtype=jnp.float32,
                    cast_params: bool = False,
                    extra_hints: Optional[dict] = None) -> StepBundle:
    """``cast_params=True`` casts fp32 master weights to the compute dtype
    ONCE at step entry, so FSDP all-gathers move bf16 instead of f32
    (half the wire + HBM traffic for every weight gather; the model's
    per-use ``astype`` then no-ops)."""
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    disp, hints = _dispatch_for(cfg, shape, mesh, dispatch)
    hints = {**_model_hints(cfg, shape, mesh), **hints,
             **(extra_hints or {})}

    state_abs = abstract_train_state(cfg, opt_cfg, param_dtype)
    state_sh = param_shardings(state_abs, mesh)
    batch_abs = abstract_batch(cfg, shape)
    batch_sh = _batch_shardings(cfg, shape, mesh)
    rep = NamedSharding(mesh, P())
    cdt = jnp.dtype(cfg.dtype)

    def loss_of(params, b):
        if cast_params:
            params = jax.tree.map(
                lambda p: p.astype(cdt)
                if p.dtype == jnp.float32 and p.ndim > 1 else p, params)
        return steps_lib.loss_fn(cfg, params, b, remat=remat, dispatch=disp)

    def step(state, batch):
        with pctx.sharding_hints(hints):
            params = state["params"]
            if microbatch > 1:
                def split(x):
                    return x.reshape((microbatch,
                                      x.shape[0] // microbatch) + x.shape[1:])
                mb = jax.tree.map(split, batch)

                def body(carry, b):
                    g_acc, loss_acc = carry
                    (loss, mets), g = jax.value_and_grad(
                        loss_of, has_aux=True)(params, b)
                    g_acc = jax.tree.map(jnp.add, g_acc, g)
                    return (g_acc, loss_acc + loss), mets

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss), mets = jax.lax.scan(
                    body, (g0, jnp.zeros((), jnp.float32)), mb)
                grads = jax.tree.map(lambda g: g / microbatch, grads)
                loss = loss / microbatch
                metrics = jax.tree.map(lambda m: m[-1], mets)
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_of, has_aux=True)(params, batch)
            new_params, new_opt, opt_metrics = adamw.update(
                params, grads, state["opt"], opt_cfg)
            metrics = {**metrics, **opt_metrics, "loss": loss}
            return {"params": new_params, "opt": new_opt}, metrics

    fn = jax.jit(step,
                 in_shardings=(state_sh, batch_sh),
                 out_shardings=(state_sh, rep),
                 donate_argnums=(0,))
    args = (_with_shardings(state_abs, state_sh),
            _with_shardings(batch_abs, batch_sh))
    return StepBundle("train", fn, args, mesh,
                      meta={"dispatch": disp, "remat": remat,
                            "microbatch": microbatch,
                            "state_shardings": state_sh,
                            "batch_shardings": batch_sh})


# -- prefill (encoder-only archs: full forward) -------------------------------


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                      dispatch: Optional[str] = None,
                      param_dtype=jnp.float32,
                      extra_hints: Optional[dict] = None) -> StepBundle:
    disp, hints = _dispatch_for(cfg, shape, mesh, dispatch)
    hints = {**_model_hints(cfg, shape, mesh), **hints,
             **(extra_hints or {})}
    params_abs = abstract_params(cfg, param_dtype)
    params_sh = param_shardings(params_abs, mesh)
    batch_abs = abstract_batch(cfg, shape)
    batch_sh = _batch_shardings(cfg, shape, mesh)
    dp = dp_axes(mesh)
    dpx = (dp if len(dp) > 1 else dp[0]) if (
        dp and shape.global_batch % axis_size(mesh, dp) == 0) else None

    if cfg.encoder_only:
        def step(params, batch):
            with pctx.sharding_hints(hints):
                return model_lib.forward(cfg, params, batch, dispatch=disp)
        out_sh = NamedSharding(mesh, P(dpx, None, None))
        fn = jax.jit(step, in_shardings=(params_sh, batch_sh),
                     out_shardings=out_sh)
        args = (_with_shardings(params_abs, params_sh),
                _with_shardings(batch_abs, batch_sh))
        return StepBundle("encode", fn, args, mesh,
                          meta={"dispatch": disp,
                                "params_shardings": params_sh})

    cache_abs = jax.eval_shape(
        lambda: model_lib.init_cache(cfg, shape.global_batch,
                                     shape.seq_len))
    cache_sh = cache_shardings(cache_abs, cfg, mesh, shape.global_batch)

    def step(params, batch):
        with pctx.sharding_hints(hints):
            return model_lib.prefill(cfg, params, batch, shape.seq_len,
                                     dispatch=disp)

    fn = jax.jit(step, in_shardings=(params_sh, batch_sh),
                 out_shardings=(NamedSharding(mesh, P(dpx, None)), cache_sh))
    args = (_with_shardings(params_abs, params_sh),
            _with_shardings(batch_abs, batch_sh))
    return StepBundle("prefill", fn, args, mesh,
                      meta={"dispatch": disp, "params_shardings": params_sh,
                            "cache_shardings": cache_sh})


# -- decode -------------------------------------------------------------------


def make_decode_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                     dispatch: Optional[str] = None,
                     param_dtype=jnp.float32,
                     cache_l_model: bool = False,
                     extra_hints: Optional[dict] = None) -> StepBundle:
    """One serve_step: each batch element appends one token against a KV /
    state cache of length seq_len.  ``cache_l_model`` shards the cache
    length dim over the "model" axis (flash-decoding)."""
    disp, hints = _dispatch_for(cfg, shape, mesh, dispatch)
    hints = {**_model_hints(cfg, shape, mesh), **hints,
             **(extra_hints or {})}
    B = shape.global_batch
    params_abs = abstract_params(cfg, param_dtype)
    params_sh = param_shardings(params_abs, mesh)
    cache_abs = abstract_cache(cfg, B, shape.seq_len)
    cache_sh = cache_shardings(cache_abs, cfg, mesh, B,
                               l_model=cache_l_model)
    dp = dp_axes(mesh)
    dpx = (dp if len(dp) > 1 else dp[0]) if (
        dp and B % axis_size(mesh, dp) == 0) else None
    tok_sh = NamedSharding(mesh, P(dpx))

    def step(params, cache, tokens, pos):
        with pctx.sharding_hints(hints):
            logits, new_cache = model_lib.decode_step(cfg, params, tokens,
                                                      pos, cache, disp)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_tok, new_cache

    fn = jax.jit(step,
                 in_shardings=(params_sh, cache_sh, tok_sh, tok_sh),
                 out_shardings=(tok_sh, cache_sh),
                 donate_argnums=(1,))
    args = (_with_shardings(params_abs, params_sh),
            _with_shardings(cache_abs, cache_sh),
            jax.ShapeDtypeStruct((B,), jnp.int32, sharding=tok_sh),
            jax.ShapeDtypeStruct((B,), jnp.int32, sharding=tok_sh))
    return StepBundle("decode", fn, args, mesh,
                      meta={"dispatch": disp, "params_shardings": params_sh,
                            "cache_shardings": cache_sh})


def make_step_bundle(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                     **kw) -> StepBundle:
    """The step a given input shape exercises (assignment semantics)."""
    if shape.kind == "train":
        return make_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, mesh, shape, **kw)
    return make_decode_step(cfg, mesh, shape, **kw)


# re-exported alias
TrainState = dict


def input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                **kw) -> tuple:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    return make_step_bundle(cfg, mesh, shape, **kw).args
