"""Fault tolerance for 1000+-node runs: straggler detection, heartbeat
watchdog, elastic mesh re-planning, and failure injection for tests.

The control flow these implement (exercised end-to-end by
``launch/train.py`` and tests/test_fault_tolerance.py):

  train loop -> heartbeat every step -> watchdog flags a hang
             -> straggler detector flags slow hosts (EWMA z-score)
             -> on failure: pick a new mesh from surviving devices
                (`plan_elastic_mesh`), restore the step-atomic checkpoint
                with reshard-on-load, continue.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax


# ---------------------------------------------------------------------------
# Straggler detection
# ---------------------------------------------------------------------------


@dataclass
class StragglerDetector:
    """Per-host step-time EWMA + variance; flags hosts > k sigma slower
    than the fleet.  On a real deployment each host reports its step wall
    time through the coordination service; here hosts are ranks in a dict.
    """

    alpha: float = 0.1
    k_sigma: float = 3.0
    min_samples: int = 8
    mean: Dict[int, float] = field(default_factory=dict)
    var: Dict[int, float] = field(default_factory=dict)
    n: Dict[int, int] = field(default_factory=dict)

    def record(self, host: int, step_s: float):
        m = self.mean.get(host, step_s)
        v = self.var.get(host, 0.0)
        d = step_s - m
        m += self.alpha * d
        v = (1 - self.alpha) * (v + self.alpha * d * d)
        self.mean[host], self.var[host] = m, v
        self.n[host] = self.n.get(host, 0) + 1

    def fleet_stats(self) -> Tuple[float, float]:
        """Robust location/scale (median + scaled MAD): a straggler must
        not contaminate the statistics used to flag it."""
        ms = sorted(m for h, m in self.mean.items()
                    if self.n.get(h, 0) >= self.min_samples)
        if not ms:
            return 0.0, 0.0
        med = ms[len(ms) // 2]
        mad = sorted(abs(x - med) for x in ms)[len(ms) // 2]
        return med, 1.4826 * mad

    def stragglers(self) -> List[int]:
        med, sd = self.fleet_stats()
        if med <= 0:
            return []
        floor = 0.05 * med  # guard against zero-variance fleets
        return [h for h, m in self.mean.items()
                if self.n.get(h, 0) >= self.min_samples
                and m > med + self.k_sigma * max(sd, floor)]


# ---------------------------------------------------------------------------
# Heartbeat watchdog
# ---------------------------------------------------------------------------


class Watchdog:
    """Deadline-based hang detection: the training loop calls
    ``beat(step)``; anyone can ask ``stalled()``.  No threads — the check
    is pulled from the supervisory loop (or a cron on a real cluster)."""

    def __init__(self, timeout_s: float = 300.0, clock=time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        self._last = clock()
        self.last_step = -1

    def beat(self, step: int):
        self._last = self._clock()
        self.last_step = step

    def stalled(self) -> bool:
        return (self._clock() - self._last) > self.timeout_s


# ---------------------------------------------------------------------------
# Elastic mesh planning
# ---------------------------------------------------------------------------


def plan_elastic_mesh(n_devices: int, model_parallel: int = 16,
                      pod_size: int = 256) -> Tuple[Tuple[int, ...],
                                                    Tuple[str, ...]]:
    """Largest usable (pod, data, model) grid from surviving devices.

    Keeps the model axis intact (TP degree is a property of the sharded
    weights' layout), shrinks data/pod: after losing nodes we drop to the
    largest data multiple that still divides the fleet.  Returns
    (shape, axis_names); build with ``jax.make_mesh``.
    """
    if n_devices < model_parallel:
        # degenerate fleet: single-axis data mesh
        return (n_devices, 1), ("data", "model")
    usable_pods = n_devices // pod_size
    if usable_pods >= 2:
        data = pod_size // model_parallel
        return (usable_pods, data, model_parallel), ("pod", "data", "model")
    data = n_devices // model_parallel
    return (data, model_parallel), ("data", "model")


def make_elastic_mesh(n_devices: Optional[int] = None,
                      model_parallel: int = 16):
    n = n_devices if n_devices is not None else len(jax.devices())
    shape, axes = plan_elastic_mesh(n, model_parallel)
    need = 1
    for s in shape:
        need *= s
    return jax.make_mesh(shape, axes,
                         devices=jax.devices()[:need])


# ---------------------------------------------------------------------------
# Failure injection (tests / chaos drills)
# ---------------------------------------------------------------------------


class InjectedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Deterministically kill the training loop at `fail_at_step` (once)."""

    fail_at_step: int = -1
    fired: bool = False

    def maybe_fail(self, step: int):
        if not self.fired and 0 <= self.fail_at_step == step:
            self.fired = True
            raise InjectedFailure(f"injected failure at step {step}")
