"""Multi-pod distribution: sharding rules, distributed step builders,
gradient compression, and fault-tolerance machinery."""
from .sharding import (batch_pspecs, cache_shardings, logical_rules,
                       param_shardings, pspec_for_param)
from .steps import (TrainState, abstract_cache, abstract_params,
                    abstract_train_state, input_specs, make_decode_step,
                    make_prefill_step, make_train_step)

__all__ = [
    "batch_pspecs", "cache_shardings", "logical_rules", "param_shardings",
    "pspec_for_param", "TrainState", "abstract_cache", "abstract_params",
    "abstract_train_state", "input_specs", "make_decode_step",
    "make_prefill_step", "make_train_step",
]
