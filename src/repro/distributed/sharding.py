"""Sharding rules: logical axes -> mesh axes, with divisibility fallback.

Scheme (Megatron/FSDP hybrid, per DESIGN.md §5):
  * "model" mesh axis:  tensor parallelism — attention heads, FFN hidden,
    vocab, MoE experts (expert parallelism), SSM/LRU inner width.
  * "data" mesh axis:   FSDP — parameters (and Adam moments, which are
    congruent trees) additionally sharded on a non-TP dimension; gathered
    on use, gradients reduce-scattered by GSPMD automatically.
  * "pod"  mesh axis:   pure data parallelism across pods — batch only.
    Parameters are NOT sharded across pods (cross-pod all-gathers every
    step would ride the slow DCI links); each pod holds a full FSDP'd
    copy and gradients all-reduce across pods once per step.

Every rule is sanitized: a mesh axis is dropped (dimension replicated)
whenever it does not evenly divide the dimension — e.g. gemma2's 8 query
heads on a 16-way model axis fall back to replicated attention weights
while its 9216 FFN still gets 16-way TP.  The fallback keeps every
(arch x shape x mesh) cell compilable; the waste it introduces is visible
in the roofline's MODEL_FLOPS/HLO_FLOPS ratio and is hillclimbed in
EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import math
import re
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import InputShape, ModelConfig

# ---------------------------------------------------------------------------
# Mesh helpers
# ---------------------------------------------------------------------------


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Batch-parallel axes: ("pod", "data") when the pod axis exists."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


# ---------------------------------------------------------------------------
# Logical axes -> mesh axes
# ---------------------------------------------------------------------------

# logical axis name -> mesh axes (None = replicated)
LOGICAL_TO_MESH = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "tensor": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "seq": ("data",),       # sequence parallelism (long-context decode)
    None: None,
}


def logical_rules() -> dict:
    return dict(LOGICAL_TO_MESH)


# (path regex, logical axes per dim).  First match wins; matched against
# the "/"-joined param path with the stacked-period axis already stripped.
_PARAM_RULES: Sequence[Tuple[str, Tuple[Optional[str], ...]]] = (
    # embeddings: vocab-parallel (Megatron)
    (r"(embed|lm_head)$", ("vocab", None)),
    (r"frontend_proj$", ("fsdp", None)),
    # MLA (must precede generic attention: names overlap)
    (r"mla/w_dq$", ("fsdp", "tensor")),
    (r"mla/w_uq$", ("fsdp", "heads", None)),
    (r"mla/w_dkv$", ("fsdp", None)),
    (r"mla/w_ukv$", ("fsdp", "heads", None)),
    (r"mla/w_o$", ("heads", None, "fsdp")),
    # attention
    (r"attn/w_q$", ("fsdp", "heads", None)),
    (r"attn/w_[kv]$", ("fsdp", "kv_heads", None)),
    (r"attn/w_o$", ("heads", None, "fsdp")),
    (r"attn/b_q$", ("heads", None)),
    (r"attn/b_[kv]$", ("kv_heads", None)),
    # MoE experts: expert-parallel + FSDP
    (r"moe/w_router$", (None, None)),
    (r"moe/(w_gate|w_up)$", ("expert", "fsdp", None)),
    (r"moe/w_down$", ("expert", None, "fsdp")),
    (r"moe/shared/(w_gate|w_up)$", ("fsdp", "tensor")),
    (r"moe/shared/w_down$", ("tensor", "fsdp")),
    # dense MLP
    (r"mlp/(w_gate|w_up)$", ("fsdp", "tensor")),
    (r"mlp/w_down$", ("tensor", "fsdp")),
    # RG-LRU
    (r"rglru/(w_x|w_gate_branch)$", ("fsdp", "tensor")),
    (r"rglru/w_out$", ("tensor", "fsdp")),
    (r"rglru/conv_w$", (None, "tensor")),
    (r"rglru/(w_r|w_i)$", (None, None, None)),
    (r"rglru/a_param$", ("tensor",)),
    # Mamba-2 SSD
    (r"ssd/w_in$", ("fsdp", None)),
    (r"ssd/w_out$", ("tensor", "fsdp")),
    (r"ssd/conv_w$", (None, None)),
    # everything else (norm scales, small biases, A_log, D, dt_bias...)
    (r".", None),
)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _sanitize(spec: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes that don't divide their dimension; drop axes not in
    the mesh (e.g. "pod" on the single-pod mesh)."""
    out = []
    for dim, axes in zip(shape, spec):
        if axes is None:
            out.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        if axes and dim % axis_size(mesh, axes) == 0:
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def pspec_for_param(path, leaf, mesh: Mesh,
                    rules=None) -> P:
    """PartitionSpec for one param leaf (body-stacked period axis aware)."""
    ps = _path_str(path)
    shape = tuple(leaf.shape)
    stacked = bool(re.search(r"(^|/)body/", ps))
    eff_shape = shape[1:] if stacked else shape
    table = rules or LOGICAL_TO_MESH
    for pat, logical in _PARAM_RULES:
        if re.search(pat, ps):
            if logical is None:
                spec = (None,) * len(eff_shape)
            else:
                assert len(logical) == len(eff_shape), (ps, logical,
                                                        eff_shape)
                spec = tuple(table.get(ax) for ax in logical)
            break
    sane = _sanitize(spec, eff_shape, mesh)
    if stacked:
        sane = P(None, *sane)
    return sane


def param_shardings(params_or_shapes, mesh: Mesh, rules=None):
    """Tree of NamedSharding congruent with the params tree (works for
    concrete arrays or ShapeDtypeStructs — also used for Adam moments)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, pspec_for_param(path, leaf, mesh, rules)),
        params_or_shapes)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------


def _dp_or_none(mesh: Mesh, b: int):
    dp = dp_axes(mesh)
    if dp and b % axis_size(mesh, dp) == 0:
        return dp if len(dp) > 1 else dp[0]
    return None


def batch_pspecs(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> dict:
    """PartitionSpecs for the input batch dict of this (arch, shape)."""
    b = shape.global_batch
    dp = _dp_or_none(mesh, b)
    if shape.kind == "decode":
        specs = {"tokens": P(dp), "pos": P(dp)}
        return specs
    specs = {}
    if cfg.frontend == "audio":
        specs["frames"] = P(dp, None, None)
    elif cfg.frontend == "vision":
        specs["patch_embeds"] = P(dp, None, None)
        specs["tokens"] = P(dp, None)
    else:
        specs["tokens"] = P(dp, None)
    if shape.kind == "train":
        specs["targets"] = P(dp, None)
    return specs


def cache_pspec_for(path, leaf, cfg: ModelConfig, mesh: Mesh,
                    batch: int, l_model: bool = False) -> P:
    """Spec for one KV/state cache leaf.

    Layouts: attention k/v (B, L, Hkv, D); MLA c_kv (B, L, r) and k_rope
    (B, L, dr); rglru h (B, W), conv (B, w-1, W); ssd h (B, H, P, N),
    conv (B, w-1, C).  Body caches carry a leading period axis.
    If the batch is shardable it goes on the DP axes; otherwise (long-
    context, batch=1) the cache *sequence* dim is sharded on "data" —
    sequence parallelism for decode.
    """
    ps = _path_str(path)
    stacked = bool(re.search(r"(^|/)body/", ps))
    shape = tuple(leaf.shape)[1:] if stacked else tuple(leaf.shape)
    dp = _dp_or_none(mesh, batch)
    name = ps.rsplit("/", 1)[-1]
    seq_shard = dp is None  # batch not shardable -> shard sequence instead
    # l_model: shard the cache length dim on the (otherwise attention-idle)
    # "model" axis — flash-decoding style; partial softmax stats reduce
    # over "model" with tiny (B, H) all-reduces.
    l_ax = "model" if l_model else ("data" if seq_shard else None)

    if name in ("k", "v"):                       # (B, L, Hkv, D)
        sane = _sanitize(
            (dp, l_ax, None if l_model else "model", None), shape, mesh)
    elif name in ("c_kv", "k_rope"):             # (B, L, r)
        sane = _sanitize((dp, l_ax, None), shape, mesh)
    elif name == "h" and len(shape) == 4:        # ssd state (B, H, P, N)
        sane = _sanitize((dp, "model", None, None), shape, mesh)
    elif name == "h":                            # rglru state (B, W)
        sane = _sanitize((dp, "model"), shape, mesh)
    elif name == "conv":                         # (B, w-1, C)
        sane = _sanitize((dp, None, "model"), shape, mesh)
    else:
        sane = _sanitize((dp,) + (None,) * (len(shape) - 1), shape, mesh)
    if stacked:
        sane = P(None, *sane)
    return sane


def cache_shardings(cache_shapes, cfg: ModelConfig, mesh: Mesh,
                    batch: int, l_model: bool = False):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_pspec_for(path, leaf, cfg, mesh, batch, l_model)),
        cache_shapes)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
