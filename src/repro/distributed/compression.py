"""Gradient compression for the cross-pod data-parallel all-reduce.

int8 error-feedback quantization: each step quantizes (grad + carried
residual) to per-tensor-scaled int8, all-reduces the int8 payload (8x less
DCI traffic than f32, 4x less than bf16), dequantizes, and carries the
quantization error into the next step (error feedback keeps SGD/Adam
convergence — Karimireddy et al., 2019).

Composition: FSDP within a pod already reduce-scatters in bf16; this
module targets the *pod* axis where links are slowest.  It is exposed as

  * pure functions (`quantize`/`dequantize`) — unit-testable,
  * `compressed_psum(grads, axis, err)` — shard_map-compatible collective,
  * `compress_grads_hook(grads, err)` — drop-in for the train step when
    running pure-DP across pods (params replicated per pod).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """-> (int8 payload, f32 scale). Symmetric per-tensor scaling."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_quantize(g: jax.Array, err: jax.Array
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback quantize: -> (payload, scale, new_err)."""
    target = g.astype(jnp.float32) + err
    q, scale = quantize(target)
    new_err = target - dequantize(q, scale)
    return q, scale, new_err


def compressed_psum(g: jax.Array, axis: str, err: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Inside shard_map: all-reduce an int8-quantized gradient over `axis`.
    Returns (mean gradient (f32), new error-feedback residual)."""
    q, scale, new_err = ef_quantize(g, err)
    # int8 payloads sum without overflow in i32; scales averaged.
    tot = jax.lax.psum(q.astype(jnp.int32), axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    mean_scale = jax.lax.psum(scale, axis) / n
    return tot.astype(jnp.float32) * mean_scale / n, new_err


def init_error_state(grads_abs) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_abs)


def compress_grads_tree(grads, err_state):
    """Local (no collective) EF-compression round-trip of a grad tree —
    models the pod-axis wire format; returns (dequantized grads, new err).
    Used by the train loop when pods==1 to keep the code path exercised."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = ef_quantize(g, e)
        out_g.append(dequantize(q, s).astype(g.dtype))
        out_e.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, out_g),
            jax.tree_util.tree_unflatten(treedef, out_e))
