"""Public export surface for the unified prediction service.

    from repro.engine import PredictionService, FeatureSchema

``PredictionService`` owns the forest, the versioned feature schema
(v1 legacy / v2 node-shape-aware), batched+cached capacity solving,
inference-engine selection (numpy / jax / pallas), and epoch/retrain
bookkeeping — see ``repro.core.prediction_service``.  ``CapacityEngine``
is the PR-1 name for the same class, kept as a true alias.
"""
from .core.prediction_service import (DRAIN_MODES, INFERENCE_ENGINES,
                                      SCHEMA_V1, SCHEMA_V2, CapacityEngine,
                                      EngineConfig, EngineStats,
                                      FeatureSchema, PredictionService,
                                      coloc_signature, get_schema)

__all__ = ["CapacityEngine", "PredictionService", "EngineConfig",
           "EngineStats", "FeatureSchema", "SCHEMA_V1", "SCHEMA_V2",
           "DRAIN_MODES", "INFERENCE_ENGINES",
           "get_schema", "coloc_signature"]
