"""Public export surface for the cluster-scale capacity engine.

    from repro.engine import CapacityEngine, EngineConfig

The engine coalesces all pending capacity solves into batched predictor
passes, caches results by canonical colocation signature, and assembles
feature matrices vectorized — see ``repro.core.capacity_engine``.
"""
from .core.capacity_engine import (CapacityEngine, EngineConfig,
                                   EngineStats, coloc_signature)

__all__ = ["CapacityEngine", "EngineConfig", "EngineStats",
           "coloc_signature"]
