"""Paper Fig 11 + Fig 12 + Table 2: scheduling cost, model inferences per
schedule, and cold-start latency with cfork / docker container init.

Extreme traces (Fig 11): ``timer`` (best case — all fast path) and
``flip`` (worst case — every schedule is a slow path).  Real-world traces
(Fig 12): four Huawei-like trace sets.  Jiagu vs Gsight (same predictor).

Jiagu's slow-path and async capacity solves run on the CapacityEngine
(the SimConfig default since the A/B parity gate): decisions and tables
are identical to the legacy per-node path, only cheaper — so measured
scheduling cost reflects the engine's coalesced/cached solving.
"""
from __future__ import annotations

import numpy as np

from .common import (CFORK_MS, DOCKER_MS, build_world, emit, make_sim,
                     save_artifact)

from repro.core import SimConfig, get_trace, realworld_suite

# Table 2 container-start systems (paper-reported init latencies, ms)
TABLE2_SYSTEMS = {
    "AWS Snapstart": 100.0, "Replayable": 54.0, "Fireworks": 50.0,
    "SOCK": 20.0, "Molecule": 8.4, "SEUSS": 7.5, "Catalyzer": 0.97,
    "Faasm": 0.5,
}


def _sched_stats(res):
    s = res.sched
    n_sched = max(s.decisions, 1)
    return {
        "sched_ms_mean": s.mean_latency_ms,
        "inferences_per_schedule": s.critical_inference_calls / n_sched,
        "rows_per_schedule": s.critical_inference_rows / n_sched,
        "fast": s.fast, "slow": s.slow,
        "fast_frac": s.fast / max(s.fast + s.slow, 1),
    }


def run(duration: int = 600, quick: bool = False):
    world = build_world()
    fns = sorted(world.specs)
    rows = []

    # -- Fig 11: extreme traces (from the platform trace registry) ---------
    # timer: scale events every period (period > keepalive so evictions
    # actually happen), load quantized to the function's saturated RPS
    traces = {
        "timer(best)": get_trace("timer")(
            fns[0], duration_s=duration, period_s=90,
            rps_per_inst=world.specs[fns[0]].saturated_rps),
        "flip(worst)": get_trace("flip")(fns[:3], duration_s=duration),
    }
    # -- Fig 12: real-world traces -----------------------------------------
    for tr in realworld_suite(fns, duration_s=duration,
                              n_traces=2 if quick else 4):
        traces[tr.name] = tr

    record = {}
    for tname, trace in traces.items():
        per_sched = {}
        for sched in ["jiagu", "gsight"]:
            res = make_sim(world, sched, trace, dual=False).run()
            per_sched[sched] = _sched_stats(res)
        j, g = per_sched["jiagu"], per_sched["gsight"]
        cost_red = 1 - j["sched_ms_mean"] / max(g["sched_ms_mean"], 1e-9)
        inf_red = 1 - j["rows_per_schedule"] / max(g["rows_per_schedule"],
                                                   1e-9)
        # paper-hardware normalization: the paper's ported Gsight spends
        # 21.78 ms of model inference per schedule; our from-scratch RFR
        # takes ~0.1 ms/call, which compresses the measured ms ratio.
        # Scale both systems' inference calls to the paper's per-call
        # cost to compare against the paper's Fig 11/12 regime.
        PAPER_GSIGHT_MS = 21.78
        per_call = PAPER_GSIGHT_MS / max(g["inferences_per_schedule"],
                                         1e-9)
        j_norm = j["inferences_per_schedule"] * per_call + 0.05
        g_norm = PAPER_GSIGHT_MS
        for init_name, init_ms in [("cfork", CFORK_MS),
                                   ("docker", DOCKER_MS)]:
            cs_j = j["sched_ms_mean"] + init_ms
            cs_g = g["sched_ms_mean"] + init_ms
            rows.append({
                "trace": tname, "init": init_name,
                "jiagu_sched_ms": round(j["sched_ms_mean"], 3),
                "gsight_sched_ms": round(g["sched_ms_mean"], 3),
                "sched_cost_reduction": round(cost_red, 3),
                "inference_reduction": round(inf_red, 3),
                "norm_cost_reduction": round(1 - j_norm / g_norm, 3),
                "jiagu_cold_ms": round(cs_j, 2),
                "gsight_cold_ms": round(cs_g, 2),
                "cold_start_reduction": round(1 - cs_j / cs_g, 3),
                "norm_cold_reduction": round(
                    1 - (j_norm + init_ms) / (g_norm + init_ms), 3),
                "jiagu_fast_frac": round(j["fast_frac"], 3),
            })
        record[tname] = per_sched
    emit(rows)

    # -- Table 2: scheduling overhead vs container-start systems ------------
    g_ms = np.mean([record[t]["gsight"]["sched_ms_mean"]
                    for t in record if t.startswith("Trace")] or
                   [record["timer(best)"]["gsight"]["sched_ms_mean"]])
    j_ms = np.mean([record[t]["jiagu"]["sched_ms_mean"]
                    for t in record if t.startswith("Trace")] or
                   [record["timer(best)"]["jiagu"]["sched_ms_mean"]])
    t2 = [{"system": name, "container_ms": init,
           "gsight_overhead": f"{g_ms / init:.1%}",
           "jiagu_overhead": f"{j_ms / init:.1%}"}
          for name, init in TABLE2_SYSTEMS.items()]
    print()
    emit(t2)
    record["table2"] = {"gsight_ms": g_ms, "jiagu_ms": j_ms}
    record["use_capacity_engine"] = SimConfig().use_capacity_engine
    save_artifact("scheduling_cost", record)
    return record


if __name__ == "__main__":
    run()
