"""Shared world-building for the paper-reproduction benchmarks."""
from __future__ import annotations

import json
import os
import sys
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (BENCH_FUNCTIONS, Cluster, GroundTruth,
                        PerfPredictor, ProfileStore, QoSStore, SimResult,
                        Simulation, build_simulation, generate_dataset,
                        realworld_suite, synthetic_functions)

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")
CFORK_MS = 8.4      # cfork container init (paper §7.2)
DOCKER_MS = 85.5    # docker container init


@dataclass
class World:
    specs: dict
    gt: GroundTruth
    store: ProfileStore
    qos: QoSStore
    predictor: PerfPredictor


def build_world(n_synthetic: int = 0, seed: int = 0,
                n_train: int = 1500, n_trees: int = 24) -> World:
    """The six paper workloads (+ optional synthetic extras), with a
    predictor trained offline on profiling/training-node data."""
    specs = dict(BENCH_FUNCTIONS)
    if n_synthetic:
        specs.update(synthetic_functions(n_synthetic, seed=seed + 1))
    gt = GroundTruth(seed=seed)
    store = ProfileStore(seed=seed)
    qos = QoSStore(store, gt)
    pred = PerfPredictor(n_trees=n_trees, max_depth=8, seed=seed)
    X, y = generate_dataset(specs, gt, store, qos, n_train, seed=seed + 2)
    pred.add_dataset(X, y)
    return World(specs, gt, store, qos, pred)


def fresh_predictor(world: World, seed: int = 0) -> PerfPredictor:
    pred = PerfPredictor(n_trees=24, max_depth=8, seed=seed)
    X, y = generate_dataset(world.specs, world.gt, world.store, world.qos,
                            1500, seed=seed + 2)
    pred.add_dataset(X, y)
    return pred


def make_sim(world: World, scheduler: str, trace, *, dual: bool = True,
             release_s: float = 45.0, keepalive_s: float = 60.0,
             init_ms: float = CFORK_MS, migrate: bool = True,
             collect_samples: bool = False,
             use_engine: Optional[bool] = None) -> Simulation:
    """``use_engine=None`` keeps the SimConfig default (CapacityEngine,
    since the engine-parity gate); ``False`` forces the legacy per-node
    reference path."""
    pred = fresh_predictor(world) if scheduler in ("jiagu", "gsight") \
        else None
    return build_simulation(
        world.specs, trace, Cluster(world.specs), world.gt, world.store,
        world.qos, scheduler, pred, dual=dual, release_s=release_s,
        keepalive_s=keepalive_s, init_ms=init_ms, migrate=migrate,
        collect_samples=collect_samples, use_engine=use_engine)


def save_artifact(name: str, record: dict):
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, f"{name}.json"), "w") as f:
        json.dump(record, f, indent=1, default=str)


def emit(rows: List[dict], keys: Optional[List[str]] = None):
    """CSV-ish stdout contract used by benchmarks.run."""
    if not rows:
        return
    keys = keys or list(rows[0])
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
