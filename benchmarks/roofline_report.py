"""§Roofline deliverable: formats the dry-run artifacts into the
per-(arch x shape x mesh) roofline table (terms, bottleneck, useful
ratio, roofline fraction) and the what-would-move-it-down notes."""
from __future__ import annotations

import glob
import json
import os

from .common import ARTIFACTS, emit

DRYRUN = os.path.join(ARTIFACTS, "dryrun")

NOTES = {
    "compute": "shard the replicated-compute dims (heads/experts) or cut "
               "dispatch overhead (sort-based MoE)",
    "memory": "remat policy / microbatching to cut activation traffic; "
              "fuse elementwise chains",
    "collective": "reshard to cut all-gathers; overlap collectives with "
                  "compute (latency-hiding scheduler)",
}


def load(tag: str = "baseline"):
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN, f"{tag}--*.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": r.get("status"),
                         "note": r.get("reason", "")})
            continue
        rf = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok", "step": r["step"],
            "compute_s": f"{rf['compute_s']:.3e}",
            "memory_s": f"{rf['memory_s']:.3e}",
            "collective_s": f"{rf['collective_s']:.3e}",
            "bottleneck": rf["bottleneck"],
            "useful_ratio": round(rf.get("useful_ratio", 0), 3),
            "roofline_frac": round(rf.get("roofline_frac", 0), 4),
            "note": NOTES.get(rf["bottleneck"], ""),
        })
    return rows


def run(tag: str = "baseline", quick: bool = False):
    rows = load(tag)
    ok = [r for r in rows if r["status"] == "ok"]
    emit(rows, keys=["arch", "shape", "mesh", "status", "bottleneck",
                     "compute_s", "memory_s", "collective_s",
                     "useful_ratio", "roofline_frac"])
    if ok:
        n_c = sum(1 for r in ok if r["bottleneck"] == "compute")
        n_m = sum(1 for r in ok if r["bottleneck"] == "memory")
        n_x = sum(1 for r in ok if r["bottleneck"] == "collective")
        print(f"\n# {len(ok)} compiled cells: {n_c} compute-bound, "
              f"{n_m} memory-bound, {n_x} collective-bound")
    return rows


if __name__ == "__main__":
    import sys
    run(sys.argv[1] if len(sys.argv) > 1 else "baseline")
