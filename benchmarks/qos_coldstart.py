"""Paper Fig 14: (a) per-function QoS violation rates on Trace A for all
systems; (b) cold starts avoided by dual-staged scaling + on-demand
migration at 45 s and 30 s release sensitivity.

Jiagu variants run on the CapacityEngine capacity path (the SimConfig
default since the A/B parity gate); results are identical to the legacy
per-node path by construction — tests/test_engine_parity.py."""
from __future__ import annotations

from .common import build_world, emit, make_sim, save_artifact

from repro.core import SimConfig, realworld_suite


def run(duration: int = 600, quick: bool = False):
    world = build_world()
    fns = sorted(world.specs)
    traces = realworld_suite(fns, duration_s=duration,
                             n_traces=2 if quick else 4)

    # (a) per-function QoS violations on Trace A
    rows_a = []
    for system, kw in [("k8s", {}), ("gsight", {}),
                       ("jiagu-nods", dict(dual=False)),
                       ("jiagu-45", dict(release_s=45.0)),
                       ("jiagu-30", dict(release_s=30.0))]:
        res = make_sim(world, system.split("-")[0], traces[0], **kw).run()
        per = res.per_fn_violation_rate()
        rows_a.append({"system": system,
                       **{fn: round(per.get(fn, 0.0), 4) for fn in fns},
                       "overall": round(res.qos_violation_rate, 4)})
    emit(rows_a)

    # (b) re-routing composition per release sensitivity
    rows_b = []
    for rel in [45.0, 30.0]:
        for trace in traces:
            res = make_sim(world, "jiagu", trace, release_s=rel).run()
            sc = res.scaling
            total_reroute = sc.logical_cold_starts + sc.blocked_logical
            rows_b.append({
                "trace": trace.name, "release_s": rel,
                "logical_cold_starts": sc.logical_cold_starts,
                "would_be_real(blocked)": sc.blocked_logical,
                "migrations": sc.migrations,
                "real_cold_starts": sc.real_cold_starts,
                "blocked_frac": round(sc.blocked_logical /
                                      max(total_reroute, 1), 4),
                "releases": sc.releases,
            })
    print()
    emit(rows_b)
    record = {"fig14a": rows_a, "fig14b": rows_b,
              "use_capacity_engine": SimConfig().use_capacity_engine}
    save_artifact("qos_coldstart", record)
    return record


if __name__ == "__main__":
    run()
