"""Paper Fig 13: normalized function density (K8s = 1.0) across the four
real-world traces, for K8s / Owl / Gsight / Jiagu-NoDS / Jiagu-45 /
Jiagu-30, plus QoS violation rates (must stay < 10%).

Jiagu variants run on the CapacityEngine capacity path (the SimConfig
default since the full-trace A/B parity gate, tests/test_engine_parity.py);
the legacy per-node path is kept as the reference oracle."""
from __future__ import annotations

from .common import build_world, emit, make_sim, save_artifact

from repro.core import SimConfig, realworld_suite

VARIANTS = [
    ("k8s", dict()),
    ("owl", dict()),
    ("gsight", dict()),
    ("jiagu-nods", dict(dual=False)),
    ("jiagu-45", dict(dual=True, release_s=45.0)),
    ("jiagu-30", dict(dual=True, release_s=30.0)),
]


def run(duration: int = 600, quick: bool = False):
    world = build_world()
    fns = sorted(world.specs)
    traces = realworld_suite(fns, duration_s=duration,
                             n_traces=2 if quick else 4)
    rows, record = [], {"use_capacity_engine":
                        SimConfig().use_capacity_engine}
    for trace in traces:
        base = None
        for name, kw in VARIANTS:
            sched = name.split("-")[0]
            res = make_sim(world, sched, trace, **kw).run()
            if name == "k8s":
                base = res.density
            rows.append({
                "trace": trace.name, "system": name,
                "density": round(res.density, 3),
                "norm_density": round(res.density / base, 3),
                "qos_violation": round(res.qos_violation_rate, 4),
                "nodes_used": res.node_seconds / max(res.ticks, 1),
            })
            record[f"{trace.name}/{name}"] = rows[-1]
    emit(rows)
    save_artifact("density", record)
    return record


if __name__ == "__main__":
    run()
