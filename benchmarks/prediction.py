"""Paper Fig 15 + Fig 16 + Fig 17-a: prediction accuracy, overfit check,
scalability in #functions, convergence for new functions, model-zoo
comparison, training time and input dimensionality."""
from __future__ import annotations

import time

import numpy as np

from .common import build_world, emit, save_artifact

from repro.core import (GroundTruth, PerfPredictor, ProfileStore, QoSStore,
                        generate_dataset, synthetic_functions)
from repro.core.predictor import (MODEL_ZOO, N_FEATURES, PerfPredictor,
                                  RandomForestRegressor, build_features)


def _rel_err(p, y):
    return float(np.mean(np.abs(np.asarray(p) - y) / np.maximum(y, 1e-9)))


def _world(n_fns, seed=0):
    specs = synthetic_functions(n_fns, seed=seed)
    gt = GroundTruth(seed=0)
    store = ProfileStore(seed=0)
    qos = QoSStore(store, gt)
    return specs, gt, store, qos


def run(quick: bool = False):
    rows = []
    record = {}

    # -- Fig 15-a: accuracy, overfit split, 6/30/60 functions ---------------
    for n_fns in ([6, 30] if quick else [6, 30, 60]):
        specs, gt, store, qos = _world(n_fns)
        n = 1500 if n_fns <= 6 else 3000
        X, y = generate_dataset(specs, gt, store, qos, n, seed=3)
        Xt, yt = generate_dataset(specs, gt, store, qos, 500, seed=77)
        pred = PerfPredictor(n_trees=24, max_depth=8, seed=0)
        pred.add_dataset(X, y)
        p = pred.predict(Xt)
        half = len(yt) // 2
        rows.append({
            "fig": "15a", "functions": n_fns,
            "err": round(_rel_err(p, yt), 4),
            "err_split1": round(_rel_err(p[:half], yt[:half]), 4),
            "err_split2": round(_rel_err(p[half:], yt[half:]), 4),
        })
    emit(rows)

    # -- Fig 15-b: convergence for a new function ----------------------------
    specs, gt, store, qos = _world(6)
    names = sorted(specs)
    old = {k: specs[k] for k in names[:5]}
    pred = PerfPredictor(n_trees=16, max_depth=8, seed=0)
    X, y = generate_dataset(old, gt, store, qos, 1200, seed=1)
    pred.add_dataset(X, y)
    mixed = {names[5]: specs[names[5]], names[0]: specs[names[0]],
             names[1]: specs[names[1]]}
    Xn, yn = generate_dataset(mixed, gt, store, qos, 120, seed=9,
                              include_solo=False)
    conv = []
    for n_added in [0, 5, 10, 20, 30]:
        for xi, yi in zip(Xn[len(conv) and conv[-1]["samples"] or 0:
                             n_added], yn[:n_added]):
            pass
        p2 = PerfPredictor(n_trees=16, max_depth=8, seed=0)
        p2._X, p2._y = list(pred._X), list(pred._y)
        for xi, yi in zip(Xn[:n_added], yn[:n_added]):
            p2._X.append(np.asarray(xi, np.float32))
            p2._y.append(float(yi))
        p2.retrain()
        err = _rel_err(p2.predict(Xn[60:]), yn[60:])
        conv.append({"fig": "15b", "samples": n_added,
                     "new_fn_err": round(err, 4)})
    print()
    emit(conv)

    # -- Fig 16: model zoo ----------------------------------------------------
    specs, gt, store, qos = _world(6)
    X, y = generate_dataset(specs, gt, store, qos, 1500, seed=3)
    Xt, yt = generate_dataset(specs, gt, store, qos, 400, seed=78)
    ly = np.log(np.maximum(y, 1e-6))
    zoo_rows = []
    for name, ctor in MODEL_ZOO.items():
        m = ctor()
        t0 = time.perf_counter()
        m.fit(X, ly)   # same log-target for all (fair comparison)
        train_s = time.perf_counter() - t0
        err = _rel_err(np.exp(np.asarray(m.predict(Xt))), yt)
        zoo_rows.append({"fig": "16", "model": name,
                         "err": round(err, 4),
                         "train_s": round(train_s, 3)})
    print()
    emit(zoo_rows)

    # -- Fig 17-a: training time + dimensionality -----------------------------
    # Jiagu function-granularity features vs instance-granularity (Gsight):
    # instance-granularity input grows with instances per node (~24 cols of
    # 13 metrics), Jiagu stays at N_FEATURES.
    inst_dims = 13 * 24 + 2
    t0 = time.perf_counter()
    RandomForestRegressor(24, 8, seed=0).fit(X, ly)
    jiagu_train = time.perf_counter() - t0
    Xb = np.repeat(X, 4, axis=1)[:, : inst_dims]
    t0 = time.perf_counter()
    RandomForestRegressor(24, 8, seed=0).fit(Xb, ly)
    inst_train = time.perf_counter() - t0
    fig17 = [{"fig": "17a", "model": "jiagu(function-gran)",
              "dims": N_FEATURES, "train_s": round(jiagu_train, 3)},
             {"fig": "17a", "model": "instance-granularity",
              "dims": inst_dims, "train_s": round(inst_train, 3)}]
    print()
    emit(fig17)

    # -- Fig 17-b: batched inference cost -------------------------------------
    pred = PerfPredictor(n_trees=24, max_depth=8, seed=0)
    pred.add_dataset(X, y)
    batch_rows = []
    for bs in [1, 10, 50, 100]:
        Xq = np.repeat(Xt[:1], bs, axis=0)
        reps = 50
        t0 = time.perf_counter()
        for _ in range(reps):
            pred.model.predict(Xq)
        ms = (time.perf_counter() - t0) / reps * 1e3
        batch_rows.append({"fig": "17b", "batch": bs,
                           "infer_ms": round(ms, 4)})
    print()
    emit(batch_rows)

    record = {"fig15a": rows, "fig15b": conv, "fig16": zoo_rows,
              "fig17a": fig17, "fig17b": batch_rows}
    save_artifact("prediction", record)
    return record


if __name__ == "__main__":
    run()
