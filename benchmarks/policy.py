"""Learned-policy study: collect DecisionTraces, train the MLP scorer,
evaluate the ``"learned"`` stack against the hand-tuned baselines.

Three phases, end to end through the ``repro.platform`` control plane:

  1. **collect** — jiagu-pipeline runs with ``pipeline.trace_features``
     on and a ``JsonlObserver`` attached; every decision's
     pre-mutation candidate feature rows, the chosen node, and the
     stages' feasibility rejections land in the event stream, plus the
     cumulative QoS counters on every tick record.
  2. **train** — ``repro.policy`` parses the streams back
     (binder-rejected candidates are masked out of the label set — a
     pointwise scorer cannot see capacity-solve feasibility and
     serving re-applies it anyway), splits deterministically, and fits
     the scorer twice: pure imitation, and the offline-RL mode that
     down-weights decisions followed by QoS breaches / cold-start
     scale-outs.  Both checkpoints land in an epoch-tagged
     ``PolicyStore`` under ``benchmarks/artifacts/``.
  3. **evaluate** — k8s / jiagu-pipeline / harvesting / learned run
     the same held-out scenario on a shared world (``gt.reseed()`` per
     system), the learned stack serving the stored imitation policy.

Gates (recorded in ``BENCH_policy.json``, enforced by the telemetry
regression gate and raised in-run):

  * ``imitation_agreement`` — holdout top-1 agreement with the jiagu
    pipeline's decisions must stay **>= 0.90** (the policy learned the
    behaviour it imitates, not noise).
  * ``learned_qos_excess`` — the learned stack's QoS violation rate
    may not exceed the no-overcommit K8s baseline by more than the
    gate's QoS tolerance (the safety envelope holds: the harvesting
    binders bound every placement, the policy only orders feasible
    candidates).
  * ``learned_density_ratio`` — learned density must stay **>= 1.0x**
    K8s (the learned ordering keeps the consolidation win).

  PYTHONPATH=src python -m benchmarks.policy [--quick | --smoke]

``--smoke`` (the ``scripts/verify.sh --policy`` arm) shrinks every
phase to seconds, relaxes the agreement floor (too few decisions to
meet the real bar), and writes no trajectory.
"""
from __future__ import annotations

import argparse
import os
import time

from .common import ARTIFACTS, emit, save_artifact

from repro.platform import JsonlObserver, Platform, PlatformConfig
from repro.policy import (PolicyStore, TrainConfig, load_traces, merge,
                          split, train_policy)
from repro.telemetry import RunReport, append_bench

KIND = "burst-storm"
#: holdout top-1 agreement with the traced jiagu decisions (hard gate;
#: relaxed under --smoke, where the dataset is a few dozen decisions)
AGREEMENT_MIN = 0.90
AGREEMENT_MIN_SMOKE = 0.50
#: learned QoS may exceed the K8s no-overcommit baseline by at most
#: this (matches the telemetry gate's absolute QoS tolerance)
QOS_EXCESS_MAX = 0.02
#: learned density must reach at least this multiple of K8s density
DENSITY_RATIO_MIN = 1.0

EVAL_SYSTEMS = ("k8s", "jiagu-pipeline", "harvesting", "learned")


def study_spec(quick: bool = False, seed: int = 0,
               smoke: bool = False) -> dict:
    collect_s = 120 if smoke else 600
    return {
        "seed": seed,
        "collect_seeds": [seed] if smoke else [seed, seed + 1, seed + 2],
        "collect": {
            "scenario": {"kind": KIND, "n_functions": 16,
                         "duration_s": collect_s, "target_nodes": 24,
                         "seed": seed},
            "scheduler": {"name": "jiagu-pipeline"},
            "prediction": {"n_train": 600, "n_trees": 8},
            "pipeline": {"trace_features": True},
        },
        "train": {"hidden": 64, "epochs": 10 if smoke else 40,
                  "lr": 3e-3, "seeds": [0] if smoke else [0, 1, 2]},
        "evaluate": {
            "scenario": {"kind": KIND, "n_functions": 16,
                         "duration_s": 60 if smoke
                         else 300 if quick else 600,
                         "target_nodes": 24, "seed": seed + 7},
            "prediction": {"n_train": 600, "n_trees": 8},
        },
    }


# ---------------------------------------------------------------------------
# Phases
# ---------------------------------------------------------------------------


def collect(spec: dict, out_dir: str) -> list:
    """Run the traced collection sweeps; return the JSONL paths."""
    import copy
    os.makedirs(out_dir, exist_ok=True)
    paths = []
    for s in spec["collect_seeds"]:
        manifest = copy.deepcopy(spec["collect"])
        manifest["scenario"]["seed"] = s
        path = os.path.join(out_dir, f"traces_s{s}.jsonl")
        t0 = time.perf_counter()
        with JsonlObserver(path) as obs:
            plat = Platform.build(config=manifest, observers=[obs])
            res = plat.run()
        print(f"# collect seed={s}: {res.sched.decisions} decisions, "
              f"density={res.density:.3f} "
              f"qos={res.qos_violation_rate:.4f} "
              f"({time.perf_counter() - t0:.1f}s) -> {path}", flush=True)
        paths.append(path)
    return paths


def fit(spec: dict, paths: list, store_dir: str, smoke: bool = False
        ) -> dict:
    """Parse, split, train imitation + offline-RL; persist the better
    imitation seed to the PolicyStore.  Returns the training metrics."""
    ds = merge(load_traces(p) for p in paths)
    train_ds, hold_ds = split(ds)
    print(f"# dataset: {len(ds)} decisions "
          f"({len(train_ds)} train / {len(hold_ds)} holdout), "
          f"{ds.skipped_versionless} versionless skipped, "
          f"{ds.skipped_unlabelled} unlabelled skipped", flush=True)
    tr = spec["train"]
    store = PolicyStore(store_dir)

    def best(mode: str, **kw):
        results = []
        for s in tr["seeds"]:
            cfg = TrainConfig(hidden=tr["hidden"], epochs=tr["epochs"],
                              lr=tr["lr"], seed=s, mode=mode, **kw)
            pol, met = train_policy(train_ds, hold_ds, cfg)
            results.append((met.get("holdout_agreement",
                                    met["train_agreement"]), pol, met))
        return max(results, key=lambda r: r[0])

    t0 = time.perf_counter()
    agree_im, pol_im, met_im = best("imitation")
    agree_rl, pol_rl, met_rl = best("offline-rl", qos_penalty=8.0,
                                    cold_penalty=1.0)
    store.save(pol_im, epoch=0, mode="imitation",
               feature_names=ds.feature_names, metrics=met_im)
    store.save(pol_rl, epoch=1, mode="offline-rl",
               feature_names=ds.feature_names, metrics=met_rl)
    print(f"# train: imitation holdout={agree_im:.4f} "
          f"offline-rl holdout={agree_rl:.4f} "
          f"({time.perf_counter() - t0:.1f}s) -> {store_dir}", flush=True)

    floor = AGREEMENT_MIN_SMOKE if smoke else AGREEMENT_MIN
    # explicit raise, not assert: the gate must fire under -O too
    if agree_im < floor:
        raise RuntimeError(
            f"policy: imitation holdout agreement {agree_im:.4f} "
            f"< {floor} — the scorer did not learn the traced "
            f"behaviour")
    return {
        "n_decisions": len(ds),
        "n_holdout": len(hold_ds),
        "skipped_versionless": ds.skipped_versionless,
        "skipped_unlabelled": ds.skipped_unlabelled,
        "imitation_agreement": round(agree_im, 4),
        "rl_agreement": round(agree_rl, 4),
    }


def evaluate(spec: dict, store_dir: str) -> list:
    """All systems on one held-out scenario and shared world; the
    learned stack serves the stored imitation policy (epoch 0)."""
    import copy
    rows = []
    scenario = world = None
    for system in EVAL_SYSTEMS:
        manifest = copy.deepcopy(spec["evaluate"])
        manifest["scheduler"] = {
            "name": "learned" if system == "learned" else system}
        if system == "learned":
            manifest["policy"] = {"store": store_dir, "epoch": 0}
        cfg = PlatformConfig.from_dict(manifest)
        plat = Platform.build(scenario=scenario, config=cfg, world=world)
        scenario, world = plat.scenario, plat.world
        world.gt.reseed()
        res = plat.run()
        row = {
            "system": system,
            "density": round(res.density, 3),
            "qos_violation": round(res.qos_violation_rate, 4),
            "requests": round(res.requests, 1),
            "decisions": res.sched.decisions,
            "placed": res.sched.instances_placed,
            "nodes_peak": res.nodes_peak,
        }
        if system == "learned":
            stats = plat.scheduler.learned_scorer.stats
            row["scored_batches"] = stats.batches
            row["stale_serves"] = stats.stale_serves
        rows.append(row)
        print(f"# eval {system}: density={row['density']} "
              f"qos={row['qos_violation']} "
              f"decisions={row['decisions']}", flush=True)
    return rows


def run(quick: bool = False, seed: int = 0, bench: bool = False,
        smoke: bool = False):
    """Collect -> train -> evaluate; gate the learned stack against the
    K8s baseline.  ``bench=True`` persists a ``RunReport`` into
    ``BENCH_policy.json`` for the regression gate and the dashboard."""
    spec = study_spec(quick=quick, seed=seed, smoke=smoke)
    out_dir = os.path.join(ARTIFACTS, "policy")
    store_dir = os.path.join(out_dir, "store")
    paths = collect(spec, out_dir)
    metrics = fit(spec, paths, store_dir, smoke=smoke)
    rows = evaluate(spec, store_dir)
    emit(rows)

    by = {r["system"]: r for r in rows}
    k8s, learned = by["k8s"], by["learned"]
    qos_excess = round(
        max(0.0, learned["qos_violation"] - k8s["qos_violation"]), 4)
    density_ratio = round(
        learned["density"] / max(k8s["density"], 1e-9), 4)
    if qos_excess > QOS_EXCESS_MAX:
        raise RuntimeError(
            f"policy: learned QoS {learned['qos_violation']} exceeds "
            f"the K8s baseline {k8s['qos_violation']} by {qos_excess} "
            f"(> {QOS_EXCESS_MAX}) — the safety envelope broke")
    if density_ratio < DENSITY_RATIO_MIN:
        raise RuntimeError(
            f"policy: learned density {learned['density']} is only "
            f"{density_ratio}x K8s {k8s['density']} "
            f"(< {DENSITY_RATIO_MIN}) — the consolidation win is gone")
    if learned["stale_serves"] != 0:
        raise RuntimeError(
            f"policy: {learned['stale_serves']} stale-epoch serves — "
            f"the hot-swap wiring lagged the service epoch")
    metrics.update({
        "learned_qos_excess": qos_excess,
        "learned_density_ratio": density_ratio,
        "stale_serves": learned["stale_serves"],
    })
    print(f"# policy gates: imitation_agreement="
          f"{metrics['imitation_agreement']} "
          f"qos_excess={qos_excess} (<= {QOS_EXCESS_MAX}) "
          f"density_ratio={density_ratio}x (>= {DENSITY_RATIO_MIN}) "
          f"stale_serves=0 => PASS", flush=True)

    record = {"kind": KIND, "spec": spec, "trace_paths": paths,
              "store": store_dir, "rows": rows, "metrics": metrics}
    save_artifact("policy", record)
    if bench:
        report = RunReport.build(
            "policy", mode="quick" if quick else "full",
            manifest={"kind": KIND, "collect": spec["collect"],
                      "train": spec["train"],
                      "evaluate": spec["evaluate"]},
            metrics=metrics, rows=rows)
        path = append_bench(report)
        print(f"# bench: appended {report.mode} run "
              f"({len(rows)} rows, git {report.git_sha}) -> {path}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="300-tick evaluation (full: 600)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale phases, relaxed agreement "
                         "floor, no trajectory write "
                         "(scripts/verify.sh --policy)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke, seed=args.seed,
        bench=not args.smoke)
