"""Model-substrate microbenchmarks: per-kernel wall time vs the jnp
oracle (CPU, small shapes — the kernels compile for TPU; interpret mode
checks dispatch overhead only), smoke train/decode step timings per
architecture family, and serving-engine throughput."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, save_artifact

from repro.configs.base import InputShape, get_smoke_config, list_archs
from repro.kernels import ops
from repro.models import model as model_lib
from repro.models import steps as steps_lib


def _time(fn, *args, reps=5, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e3


def kernel_bench():
    rows = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    B, S, D = 4, 256, 64
    q = jax.random.normal(ks[0], (B, S, 4, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, 2, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, 2, D), jnp.float32)
    for kind, window in [("global", 0), ("local", 64)]:
        ms_ref = _time(lambda: ops.attention_op(q, k, v, kind=kind,
                                                window=window,
                                                use_pallas=False))
        rows.append({"kernel": f"attention/{kind}", "engine": "jnp-oracle",
                     "ms": round(ms_ref, 2)})
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, 512, 256)))
    b = jax.random.normal(ks[1], (2, 512, 256))
    rows.append({"kernel": "rglru_scan", "engine": "jnp-oracle",
                 "ms": round(_time(lambda: ops.rglru_op(
                     a, b, use_pallas=False)), 2)})
    x = jax.random.normal(ks[0], (2, 256, 8, 32))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, 256, 8)))
    A = -jnp.ones(8)
    Bm = jax.random.normal(ks[2], (2, 256, 8, 16))
    rows.append({"kernel": "ssd_scan", "engine": "jnp-oracle",
                 "ms": round(_time(lambda: ops.ssd_op(
                     x, dt, A, Bm, Bm, use_pallas=False)), 2)})
    return rows


def arch_smoke_bench(quick: bool = False):
    rows = []
    shape = InputShape("bench", 128, 2, "train")
    archs = list_archs() if not quick else ["gemma2-2b", "mamba2-2.7b",
                                            "deepseek-v2-236b"]
    for arch in archs:
        cfg = get_smoke_config(arch)
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
        batch = steps_lib.make_train_batch(cfg, shape)
        lfn = jax.jit(lambda p, b: steps_lib.loss_fn(cfg, p, b)[0])
        ms = _time(lfn, params, batch, reps=3)
        row = {"arch": arch, "smoke_fwd_loss_ms": round(ms, 1)}
        if not cfg.encoder_only:
            logits, cache = jax.jit(
                lambda p, b: model_lib.prefill(cfg, p, b, 160))(
                params, {k: v for k, v in batch.items()
                         if k not in ("targets",)})
            dfn = jax.jit(lambda p, t, pos, c: model_lib.decode_step(
                cfg, p, t, pos, c))
            toks = jnp.zeros((2,), jnp.int32)
            pos = jnp.full((2,), 128, jnp.int32)
            row["smoke_decode_ms"] = round(
                _time(dfn, params, toks, pos, cache, reps=10), 2)
        rows.append(row)
    return rows


def run(quick: bool = False):
    k = kernel_bench()
    emit(k)
    print()
    a = arch_smoke_bench(quick)
    emit(a)
    save_artifact("model_perf", {"kernels": k, "archs": a})
    return {"kernels": k, "archs": a}


if __name__ == "__main__":
    run()
