"""Cluster-scale capacity-solve scaling study: legacy per-node path vs
the CapacityEngine (coalesced + cached + vectorized), 24 -> 512 nodes.

Each cluster size is populated with nodes drawn from a fixed pool of
colocation patterns — the regime a real fleet is in, where most nodes
look like a few dozen archetypes.  For each size we drain the whole
cluster's capacity tables twice per path:

  * legacy  — ``update_capacity_table`` node by node (one predictor call
              per (node, function), Python row assembly, full m-sweep)
  * engine  — ``CapacityEngine.update_nodes`` (one coalesced drain:
              a handful of batched predictor calls, signature cache,
              vectorized assembly, chunked early-exit m-sweep)

and assert the resulting capacity tables are identical.  The second
(warm) engine drain shows the steady-state cost once the signature cache
is populated.  Acceptance target: >= 5x wall-time AND predictor-call
reduction at 256 nodes, tables equal.
"""
from __future__ import annotations

import time

import numpy as np

from .common import build_world, emit, save_artifact

from repro.core import update_capacity_table
from repro.core.cluster import Node
from repro.core.interference import NodeResources
from repro.engine import CapacityEngine, EngineConfig
from repro.telemetry import RunReport, append_bench

M_MAX = 16
N_PATTERNS = 24


def _pattern_pool(specs, rng, n_patterns: int):
    names = sorted(specs)
    pool = []
    for _ in range(n_patterns):
        k = int(rng.integers(1, 4))
        pat = {}
        for g in rng.choice(names, size=k, replace=False):
            pat[g] = (int(rng.integers(1, 6)), int(rng.integers(0, 3)))
        pool.append(pat)
    return pool


def _build_nodes(specs, n_nodes: int, seed: int):
    rng = np.random.default_rng(seed)
    pool = _pattern_pool(specs, rng, N_PATTERNS)
    nodes = []
    for _ in range(n_nodes):
        node = Node(NodeResources())
        for g, (ns, nc) in pool[rng.integers(len(pool))].items():
            node.state(g).n_sat = ns
            node.state(g).n_cached = nc
        nodes.append(node)
    return nodes


def _tables(nodes):
    return [sorted((fn, e.capacity) for fn, e in n.table.items())
            for n in nodes]


def _clear(nodes):
    for n in nodes:
        n.table.clear()


def run(quick: bool = False, bench: bool = False):
    """``bench=True`` (the driver/__main__ path) persists a
    ``RunReport`` into ``BENCH_capacity_engine.json`` for the
    regression gate; tests calling this directly leave the repo root
    untouched."""
    world = build_world(n_synthetic=6)
    pred = world.predictor
    sizes = [24, 128, 256] if quick else [24, 64, 128, 256, 512]
    rows = []
    for n_nodes in sizes:
        nodes = _build_nodes(world.specs, n_nodes, seed=n_nodes)

        # -- legacy: per-node, per-function solves ---------------------
        calls0, rows0 = pred.inference_calls, pred.inference_count
        t0 = time.perf_counter()
        for node in nodes:
            update_capacity_table(pred, world.store, world.qos,
                                  world.specs, node, m_max=M_MAX)
        legacy_s = time.perf_counter() - t0
        legacy_calls = pred.inference_calls - calls0
        legacy_rows = pred.inference_count - rows0
        ref = _tables(nodes)
        _clear(nodes)

        # -- engine: one coalesced drain, cold cache -------------------
        engine = CapacityEngine(pred, world.store, world.qos, world.specs,
                                EngineConfig(m_max=M_MAX))
        calls0, rows0 = pred.inference_calls, pred.inference_count
        t0 = time.perf_counter()
        engine.update_nodes(nodes, m_max=M_MAX)
        engine_s = time.perf_counter() - t0
        engine_calls = pred.inference_calls - calls0
        engine_rows = pred.inference_count - rows0
        got = _tables(nodes)
        assert got == ref, f"capacity tables diverged at {n_nodes} nodes"
        _clear(nodes)

        # -- engine again: warm signature cache ------------------------
        t0 = time.perf_counter()
        engine.update_nodes(nodes, m_max=M_MAX)
        warm_s = time.perf_counter() - t0
        assert _tables(nodes) == ref, "warm-cache tables diverged"

        rows.append({
            "nodes": n_nodes,
            "scenarios": sum(len(t) for t in ref),
            "legacy_ms": round(legacy_s * 1e3, 2),
            "engine_ms": round(engine_s * 1e3, 2),
            "warm_ms": round(warm_s * 1e3, 2),
            "speedup": round(legacy_s / max(engine_s, 1e-9), 2),
            "warm_speedup": round(legacy_s / max(warm_s, 1e-9), 2),
            "legacy_calls": legacy_calls,
            "engine_calls": engine_calls,
            "call_reduction": round(legacy_calls / max(engine_calls, 1), 1),
            "legacy_rows": legacy_rows,
            "engine_rows": engine_rows,
            "unique_solves": engine.stats.unique_solves,
            "cache_hits": engine.stats.cache_hits,
            "coalesced_dupes": engine.stats.coalesced_dupes,
            "tables_equal": True,
        })
        emit(rows[-1:])

    save_artifact("capacity_engine_scaling", {"m_max": M_MAX,
                                              "n_patterns": N_PATTERNS,
                                              "rows": rows})
    at256 = [r for r in rows if r["nodes"] == 256]
    if at256:
        r = at256[0]
        ok = r["speedup"] >= 5.0 and r["call_reduction"] >= 5.0
        print(f"# 256-node acceptance: speedup={r['speedup']}x "
              f"calls {r['legacy_calls']}->{r['engine_calls']} "
              f"({r['call_reduction']}x) tables_equal={r['tables_equal']} "
              f"=> {'PASS' if ok else 'FAIL'}")
    if bench:
        top = rows[-1]
        report = RunReport.build(
            "capacity_engine", mode="quick" if quick else "full",
            manifest={"m_max": M_MAX, "n_patterns": N_PATTERNS,
                      "sizes": sizes},
            metrics={"speedup_max_size": top["speedup"],
                     "warm_speedup_max_size": top["warm_speedup"],
                     "call_reduction_max_size": top["call_reduction"],
                     "tables_equal_all": all(r["tables_equal"]
                                             for r in rows)},
            rows=rows)
        path = append_bench(report)
        print(f"# bench: appended {report.mode} run "
              f"({len(rows)} rows, git {report.git_sha}) -> {path}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick, bench=True)
