"""Cluster-scale capacity-solve scaling study: legacy per-node path vs
the CapacityEngine host drain vs the device-resident fused drain,
24 -> 4096 nodes.

Each cluster size is populated with nodes drawn from a fixed pool of
colocation patterns — the regime a real fleet is in, where most nodes
look like a few dozen archetypes.  For each size we drain the whole
cluster's capacity tables per path:

  * legacy  — ``update_capacity_table`` node by node (one predictor call
              per (node, function), Python row assembly, full m-sweep)
  * engine  — ``CapacityEngine.update_nodes`` (one coalesced drain:
              a handful of batched predictor calls, signature cache,
              vectorized assembly, chunked early-exit m-sweep)
  * device  — ``EngineConfig(drain="device")``: the whole drain packed
              into ONE (S, M, R, F) scenario tensor, the full m-sweep
              fused into a single forest pass (``rfr_sweep_op``; Pallas
              on TPU, the jnp gather sweep on CPU), capacities resolved
              by a device-side gather.  A second (warm) drain shows the
              steady-state cost once the device cache is populated.

and assert all resulting capacity tables are identical (the solver's
bit-compatibility contract).  The legacy O(nodes) path is only run up
to 512 nodes; the extended sizes (1024, 4096) compare the device drain
against the host-engine oracle.  Acceptance targets: >= 5x wall-time
AND predictor-call reduction at 256 nodes; device per-solve latency
flat in cluster size (log-log slope < 0.5 across the >= 128-node rows,
recorded as ``device_per_solve_slope`` and gated).
"""
from __future__ import annotations

import time

import numpy as np

from .common import build_world, emit, save_artifact

from repro.core import update_capacity_table
from repro.core.cluster import Node
from repro.core.interference import NodeResources
from repro.engine import CapacityEngine, EngineConfig
from repro.telemetry import RunReport, append_bench

M_MAX = 16
N_PATTERNS = 24
#: legacy per-node solving is O(nodes) with Python row assembly — above
#: this it only burns benchmark time proving the same linearity
LEGACY_MAX_NODES = 512
#: device-drain-only extension (vs the host-engine oracle)
EXTENDED_SIZES = [1024, 4096]
#: device per-solve latency must stay flat: log-log slope of
#: us-per-solve vs nodes over the >= SLOPE_MIN_NODES rows
SLOPE_MAX = 0.5
SLOPE_MIN_NODES = 100


def _pattern_pool(specs, rng, n_patterns: int):
    names = sorted(specs)
    pool = []
    for _ in range(n_patterns):
        k = int(rng.integers(1, 4))
        pat = {}
        for g in rng.choice(names, size=k, replace=False):
            pat[g] = (int(rng.integers(1, 6)), int(rng.integers(0, 3)))
        pool.append(pat)
    return pool


def _build_nodes(specs, n_nodes: int, seed: int):
    rng = np.random.default_rng(seed)
    pool = _pattern_pool(specs, rng, N_PATTERNS)
    nodes = []
    for _ in range(n_nodes):
        node = Node(NodeResources())
        for g, (ns, nc) in pool[rng.integers(len(pool))].items():
            node.state(g).n_sat = ns
            node.state(g).n_cached = nc
        nodes.append(node)
    return nodes


def _tables(nodes):
    return [sorted((fn, e.capacity) for fn, e in n.table.items())
            for n in nodes]


def _clear(nodes):
    for n in nodes:
        n.table.clear()


def _device_engine() -> str:
    """Pallas kernel on TPU; the jnp gather sweep on CPU (interpret-mode
    Pallas would benchmark the emulator, not the drain)."""
    import jax
    return "pallas" if jax.default_backend() == "tpu" else "jax"


def run(quick: bool = False, bench: bool = False):
    """``bench=True`` (the driver/__main__ path) persists a
    ``RunReport`` into ``BENCH_capacity_engine.json`` for the
    regression gate; tests calling this directly leave the repo root
    untouched."""
    world = build_world(n_synthetic=6)
    pred = world.predictor
    sizes = [24, 128, 256] if quick else [24, 64, 128, 256, 512]
    all_sizes = sizes + EXTENDED_SIZES
    dev_engine = _device_engine()
    rows = []
    for n_nodes in all_sizes:
        nodes = _build_nodes(world.specs, n_nodes, seed=n_nodes)
        run_legacy = n_nodes <= LEGACY_MAX_NODES

        legacy_s = legacy_calls = legacy_rows = None
        if run_legacy:
            # -- legacy: per-node, per-function solves -----------------
            calls0, rows0 = pred.inference_calls, pred.inference_count
            t0 = time.perf_counter()
            for node in nodes:
                update_capacity_table(pred, world.store, world.qos,
                                      world.specs, node, m_max=M_MAX)
            legacy_s = time.perf_counter() - t0
            legacy_calls = pred.inference_calls - calls0
            legacy_rows = pred.inference_count - rows0
            ref = _tables(nodes)
            _clear(nodes)

        # -- engine: one coalesced drain, cold cache -------------------
        engine = CapacityEngine(pred, world.store, world.qos, world.specs,
                                EngineConfig(m_max=M_MAX))
        calls0, rows0 = pred.inference_calls, pred.inference_count
        t0 = time.perf_counter()
        engine.update_nodes(nodes, m_max=M_MAX)
        engine_s = time.perf_counter() - t0
        engine_calls = pred.inference_calls - calls0
        engine_rows = pred.inference_count - rows0
        got = _tables(nodes)
        if run_legacy:
            assert got == ref, f"capacity tables diverged at {n_nodes} nodes"
        else:
            ref = got               # host engine is the oracle out here
        _clear(nodes)

        # -- engine again: warm signature cache ------------------------
        t0 = time.perf_counter()
        engine.update_nodes(nodes, m_max=M_MAX)
        warm_s = time.perf_counter() - t0
        assert _tables(nodes) == ref, "warm-cache tables diverged"
        _clear(nodes)

        # -- device: fused single-pass m-sweep -------------------------
        device = CapacityEngine(pred, world.store, world.qos, world.specs,
                                EngineConfig(m_max=M_MAX, drain="device"))
        prev_engine = pred.engine
        pred.engine = dev_engine
        try:
            # warm the jit/Pallas compile for this size's padded shape,
            # then invalidate so the timed drain re-solves everything
            device.update_nodes(nodes, m_max=M_MAX)
            _clear(nodes)
            device.invalidate()
            t0 = time.perf_counter()
            device.update_nodes(nodes, m_max=M_MAX)
            device_s = time.perf_counter() - t0
            assert _tables(nodes) == ref, \
                f"device capacity tables diverged at {n_nodes} nodes"
            device_calls = device.stats.predict_calls // 2  # minus warm-up
            _clear(nodes)
            t0 = time.perf_counter()
            device.update_nodes(nodes, m_max=M_MAX)
            device_warm_s = time.perf_counter() - t0
            assert _tables(nodes) == ref, "warm device tables diverged"
        finally:
            pred.engine = prev_engine

        scenarios = sum(len(t) for t in ref)
        rows.append({
            "nodes": n_nodes,
            "scenarios": scenarios,
            "legacy_ms": round(legacy_s * 1e3, 2) if run_legacy else None,
            "engine_ms": round(engine_s * 1e3, 2),
            "warm_ms": round(warm_s * 1e3, 2),
            "device_ms": round(device_s * 1e3, 2),
            "device_warm_ms": round(device_warm_s * 1e3, 2),
            "device_us_per_solve": round(device_s * 1e6 / scenarios, 2),
            "speedup": round(legacy_s / max(engine_s, 1e-9), 2)
            if run_legacy else None,
            "warm_speedup": round(legacy_s / max(warm_s, 1e-9), 2)
            if run_legacy else None,
            "legacy_calls": legacy_calls,
            "engine_calls": engine_calls,
            "device_calls": device_calls,
            "call_reduction": round(legacy_calls / max(engine_calls, 1), 1)
            if run_legacy else None,
            "legacy_rows": legacy_rows,
            "engine_rows": engine_rows,
            "unique_solves": engine.stats.unique_solves,
            "cache_hits": engine.stats.cache_hits,
            "coalesced_dupes": engine.stats.coalesced_dupes,
            "tables_equal": True,
        })
        emit(rows[-1:])

    # device scaling law: per-solve latency vs cluster size (log-log).
    # <= 0 means flat-or-amortizing; SLOPE_MAX bounds regressions.
    fit = [(r["nodes"], r["device_us_per_solve"]) for r in rows
           if r["nodes"] >= SLOPE_MIN_NODES]
    slope = float(np.polyfit(np.log([n for n, _ in fit]),
                             np.log([u for _, u in fit]), 1)[0]) \
        if len(fit) >= 2 else 0.0
    assert slope < SLOPE_MAX, \
        f"device per-solve latency grows with cluster size " \
        f"(log-log slope {slope:.3f} >= {SLOPE_MAX})"
    print(f"# device per-solve slope ({len(fit)} sizes >= "
          f"{SLOPE_MIN_NODES} nodes, engine={dev_engine}): {slope:.3f} "
          f"=> {'PASS' if slope < SLOPE_MAX else 'FAIL'}")

    save_artifact("capacity_engine_scaling", {"m_max": M_MAX,
                                              "n_patterns": N_PATTERNS,
                                              "device_engine": dev_engine,
                                              "rows": rows})
    at256 = [r for r in rows if r["nodes"] == 256]
    if at256:
        r = at256[0]
        ok = r["speedup"] >= 5.0 and r["call_reduction"] >= 5.0
        print(f"# 256-node acceptance: speedup={r['speedup']}x "
              f"calls {r['legacy_calls']}->{r['engine_calls']} "
              f"({r['call_reduction']}x) tables_equal={r['tables_equal']} "
              f"=> {'PASS' if ok else 'FAIL'}")
    if bench:
        top = [r for r in rows if r["speedup"] is not None][-1]
        report = RunReport.build(
            "capacity_engine", mode="quick" if quick else "full",
            manifest={"m_max": M_MAX, "n_patterns": N_PATTERNS,
                      "sizes": all_sizes, "device_engine": dev_engine},
            metrics={"speedup_max_size": top["speedup"],
                     "warm_speedup_max_size": top["warm_speedup"],
                     "call_reduction_max_size": top["call_reduction"],
                     "device_per_solve_slope": round(slope, 4),
                     "device_us_per_solve_max_size":
                         rows[-1]["device_us_per_solve"],
                     "tables_equal_all": all(r["tables_equal"]
                                             for r in rows)},
            rows=rows)
        path = append_bench(report)
        print(f"# bench: appended {report.mode} run "
              f"({len(rows)} rows, git {report.git_sha}) -> {path}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick, bench=True)
