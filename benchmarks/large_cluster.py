"""Large-cluster scenario study: density / QoS / scheduling cost at
64 -> 512 nodes, plus the full-trace engine-vs-legacy A/B parity harness.

The paper's evaluation stops at a 24-node testbed.  With the
CapacityEngine the simulator affords production-scale clusters, so this
study sweeps the scenario suite (correlated burst storms, migrating
diurnal peaks, heavy-tailed cold-start churn, the Azure-like sparse long
tail) over heterogeneous fleets sized 64 -> 512 nodes and reports, per
(scenario, size):

  * density (instances per active node) for Jiagu vs the K8s
    requested-resource baseline, and the normalized ratio (Fig-13 style),
  * QoS violation rate (must hold the paper's <10% bar at scale),
  * scheduling cost: mean decision latency, critical-path inference rows
    per schedule, fast-path fraction,
  * engine telemetry: predictor calls, signature-cache hit rate.

Every run is driven through the ``repro.platform`` control plane: the
sweep derives one ``PlatformConfig`` manifest (a plain dict,
``PlatformConfig.from_dict``-validated) per (scenario, size, system)
from ``study_spec``'s base manifest — no bespoke argument plumbing —
and ``Platform.build`` assembles the world/scheduler/autoscaler stack.

``ab_parity`` is the gate that let ``SimConfig.use_capacity_engine``
default to True: the same scenario is simulated twice — legacy per-node
capacity solving vs the CapacityEngine — and end-to-end metrics
(capacity tables, density, QoS, scheduling/scaling counters) must match.

  PYTHONPATH=src python -m benchmarks.large_cluster [--quick]
"""
from __future__ import annotations

import argparse
import contextlib
import copy
import os
import time

import numpy as np

from .common import ARTIFACTS, emit, save_artifact

from repro.core import scenario_world
from repro.platform import (JsonlObserver, Platform, PlatformConfig,
                            scenario_from_config)
from repro.telemetry import RunReport, append_bench

N_FUNCTIONS = 24
STUDY_KINDS = ("burst-storm", "diurnal-shift", "coldstart-churn",
               "azure-sparse")
#: density/QoS sweep systems: the no-overcommit baseline, the paper's
#: scheduler, and the pipeline-native harvesting policy
STUDY_SYSTEMS = ("k8s", "jiagu", "harvesting")
#: legacy-vs-pipeline placement-parity pairs and the cluster size each
#: is gated at (gsight is per-instance-inference bound, so its parity
#: runs on a smaller fleet)
PIPELINE_PAIRS = (("k8s", "k8s-pipeline", 256),
                  ("owl", "owl-pipeline", 256),
                  ("jiagu", "jiagu-pipeline", 256),
                  ("gsight", "gsight-pipeline", 32))


def study_spec(quick: bool = False, seed: int = 0) -> dict:
    """The whole study as data: sweep axes + the base ``PlatformConfig``
    manifest every run derives from (``benchmarks.run`` passes this
    through, and per-run manifests go through
    ``PlatformConfig.from_dict`` for strict validation)."""
    return {
        "sizes": [64, 128] if quick else [64, 128, 256, 512],
        "kinds": list(STUDY_KINDS[:2] if quick else STUDY_KINDS),
        "seed": seed,
        # NB: n_train is held at full strength even in quick mode — an
        # under-trained predictor moves the study into the
        # overcommit-miss regime (QoS above the paper's bar).  Only the
        # forest is slightly smaller (20 vs 24 trees); the world is
        # built once, so the cost is a few seconds either way.
        "base": {
            "scenario": {"n_functions": N_FUNCTIONS,
                         "duration_s": 180 if quick else 600,
                         "seed": seed, "spec_seed": seed + 5},
            "prediction": {"n_train": 2000,
                           "n_trees": 20 if quick else 24},
        },
    }


def _series_nan_free(res) -> bool:
    return bool(np.isfinite(np.asarray(res.density_series)).all())


def _result_row(kind: str, target_nodes: int, system: str, res,
                wall_s: float) -> dict:
    s = res.sched
    a = res.scaling
    n_sched = max(s.decisions, 1)
    return {
        "scenario": kind, "target_nodes": target_nodes, "system": system,
        "density": round(res.density, 3),
        "qos_violation": round(res.qos_violation_rate, 4),
        "mean_nodes": round(res.node_seconds / max(res.ticks, 1), 1),
        "peak_nodes": res.nodes_peak,
        "sched_ms_mean": round(s.mean_latency_ms, 4),
        "sched_ms_p50": round(s.p50_latency_ms, 4),
        "sched_ms_p99": round(s.p99_latency_ms, 4),
        "cold_ms_p50": round(a.cold_start_ms.p50, 4),
        "cold_ms_p99": round(a.cold_start_ms.p99, 4),
        "rows_per_schedule": round(s.critical_inference_rows / n_sched, 2),
        "fast_frac": round(s.fast / max(s.fast + s.slow, 1), 3),
        "nan_free": _series_nan_free(res),
        "wall_s": round(wall_s, 1),
    }


def _run_manifest(manifest: dict):
    """One run from one manifest dict, through the Platform path (world
    built from scratch — the A/B arms depend on that)."""
    plat = Platform.build(config=PlatformConfig.from_dict(manifest))
    return plat, plat.run()


def run_study(spec: dict):
    """The density/QoS/cost sweep, one manifest per run.  One function
    population and one trained predictor are shared by every scenario
    (they differ only in trace program and cluster size).  Every run's
    observer streams (ticks, scheduling decisions with their
    ``DecisionTrace`` summaries, scaling transitions, retrains) are
    persisted to ``artifacts/events/*.jsonl`` for cross-run
    dashboards."""
    world = None
    rows = []
    events_dir = spec.get("events_dir", os.path.join(ARTIFACTS, "events"))
    for kind in spec["kinds"]:
        for target in spec["sizes"]:
            scenario = None
            base = None
            for system in spec.get("systems", STUDY_SYSTEMS):
                manifest = copy.deepcopy(spec["base"])
                manifest["scenario"].update(kind=kind,
                                            target_nodes=target)
                manifest.setdefault("scheduler", {})["name"] = system
                cfg = PlatformConfig.from_dict(manifest)
                if scenario is None:
                    scenario = scenario_from_config(cfg)
                if world is None:
                    world = scenario_world(
                        scenario, n_train=cfg.prediction.n_train,
                        n_trees=cfg.prediction.n_trees)
                obs = JsonlObserver(
                    os.path.join(events_dir,
                                 f"{kind}_{target}_{system}.jsonl"),
                    tick_every=10,
                    meta={"manifest": cfg.to_dict()}) \
                    if events_dir else None
                # the context manager closes (and flushes) the event
                # artifact even when a run raises mid-sweep
                with obs if obs is not None else contextlib.nullcontext():
                    t0 = time.perf_counter()
                    plat = Platform.build(scenario=scenario, config=cfg,
                                          world=world,
                                          observers=[obs] if obs else ())
                    res = plat.run()
                row = _result_row(kind, target, system, res,
                                  time.perf_counter() - t0)
                if system == "k8s":
                    base = res.density
                # no k8s arm in a custom systems list -> no normalization
                row["norm_density"] = \
                    round(res.density / max(base, 1e-9), 3) \
                    if base is not None else ""
                if plat.service is not None:
                    st = plat.service.stats
                    row["engine_predict_calls"] = st.predict_calls
                    row["engine_cache_hits"] = st.cache_hits
                    row["engine_unique_solves"] = st.unique_solves
                rows.append(row)
                print(f"# {kind}@{target} {system}: "
                      f"density={row['density']} "
                      f"qos={row['qos_violation']} "
                      f"({row['wall_s']}s)", flush=True)
    # one table, one header: k8s rows leave the engine_* columns empty
    keys = list(rows[0]) + ["norm_density", "engine_predict_calls",
                            "engine_cache_hits", "engine_unique_solves"]
    emit(rows, keys=list(dict.fromkeys(keys)))
    return rows


# ---------------------------------------------------------------------------
# Full-trace A/B: legacy per-node capacity solving vs CapacityEngine
# ---------------------------------------------------------------------------


def _arm(use_engine: bool, kind: str, duration: int, target_nodes: int,
         n_functions: int, seed: int, migrate: bool):
    """One A/B arm, built from scratch so both arms start bit-identical
    (same seeds -> same specs, ground truth, profiles, forest).  The
    only difference between the arms is the manifest's
    ``simulation.use_capacity_engine`` flag."""
    manifest = {
        "scenario": {"kind": kind, "n_functions": n_functions,
                     "duration_s": duration,
                     "target_nodes": target_nodes, "seed": seed},
        "scheduler": {"name": "jiagu"},
        "scaling": {"migrate": migrate},
        "prediction": {"n_train": 1000, "n_trees": 16},
        "simulation": {"use_capacity_engine": use_engine},
    }
    plat, res = _run_manifest(manifest)
    tables = sorted(
        tuple(sorted((fn, e.capacity) for fn, e in node.table.items()))
        for node in plat.cluster.nodes.values())
    return res, tables


def ab_parity(kind: str = "burst-storm", duration: int = 180,
              target_nodes: int = 24, n_functions: int = 8, seed: int = 0,
              migrate: bool = True) -> dict:
    """Run the same full trace through the legacy path and the engine and
    compare end-to-end metrics.  Returns the comparison record; raises if
    parity is broken (this is the default-flip gate)."""
    legacy, tables_l = _arm(False, kind, duration, target_nodes,
                            n_functions, seed, migrate)
    engine, tables_e = _arm(True, kind, duration, target_nodes,
                            n_functions, seed, migrate)
    record = {
        "kind": kind, "duration_s": duration, "target_nodes": target_nodes,
        "legacy": {"density": legacy.density,
                   "qos_violation": legacy.qos_violation_rate,
                   "decisions": legacy.sched.decisions,
                   "fast": legacy.sched.fast, "slow": legacy.sched.slow,
                   "placed": legacy.sched.instances_placed,
                   "real_cold": legacy.scaling.real_cold_starts,
                   "logical_cold": legacy.scaling.logical_cold_starts},
        "engine": {"density": engine.density,
                   "qos_violation": engine.qos_violation_rate,
                   "decisions": engine.sched.decisions,
                   "fast": engine.sched.fast, "slow": engine.sched.slow,
                   "placed": engine.sched.instances_placed,
                   "real_cold": engine.scaling.real_cold_starts,
                   "logical_cold": engine.scaling.logical_cold_starts},
        "tables_equal": tables_l == tables_e,
    }
    # explicit raises, not asserts: this gate must also fire under -O
    if not record["tables_equal"]:
        raise RuntimeError("A/B parity: capacity tables diverged")
    for key in ("decisions", "fast", "slow", "placed", "real_cold",
                "logical_cold"):
        if record["legacy"][key] != record["engine"][key]:
            raise RuntimeError(
                f"A/B parity: {key} diverged "
                f"({record['legacy'][key]} vs {record['engine'][key]})")
    if not np.isclose(legacy.density, engine.density, rtol=1e-9):
        raise RuntimeError("A/B parity: density diverged")
    if not np.isclose(legacy.qos_violation_rate, engine.qos_violation_rate,
                      rtol=1e-9, atol=1e-12):
        raise RuntimeError("A/B parity: QoS violation rate diverged")
    record["parity"] = True
    return record


# ---------------------------------------------------------------------------
# Pipeline parity: legacy monolithic schedule() vs the decision pipeline
# ---------------------------------------------------------------------------


def _placement_state(plat) -> list:
    """The cluster's final placement as a canonical comparable value."""
    return sorted(
        tuple(sorted((fn, s.n_sat, s.n_cached)
                     for fn, s in node.funcs.items()))
        for node in plat.cluster.nodes.values())


def _parity_arm(system: str, kind: str, duration: int, target_nodes: int,
                n_functions: int, seed: int):
    manifest = {
        "scenario": {"kind": kind, "n_functions": n_functions,
                     "duration_s": duration,
                     "target_nodes": target_nodes, "seed": seed},
        "scheduler": {"name": system},
        "prediction": {"n_train": 1000, "n_trees": 16},
    }
    plat, res = _run_manifest(manifest)
    return plat, res


def pipeline_parity(kind: str = "burst-storm", duration: int = 120,
                    n_functions: int = 12, seed: int = 0,
                    pairs=PIPELINE_PAIRS) -> dict:
    """The decision-pipeline re-expression gate: each legacy scheduler
    and its pipeline stack run the same full trace from identical world
    state; placements (final per-node instance layout), density, QoS,
    and every scheduling/scaling counter must be identical.  Raises on
    any divergence — this is what lets future policies build on the
    pipeline stages without re-validating the baselines."""
    rows = []
    for legacy_name, pipeline_name, target_nodes in pairs:
        arms = {}
        for system in (legacy_name, pipeline_name):
            t0 = time.perf_counter()
            plat, res = _parity_arm(system, kind, duration, target_nodes,
                                    n_functions, seed)
            s, a = res.sched, res.scaling
            arms[system] = {
                "density": res.density,
                "qos_violation": res.qos_violation_rate,
                "requests": res.requests,
                "nodes_peak": res.nodes_peak,
                "counters": (s.decisions, s.fast, s.slow,
                             s.instances_placed, s.failed,
                             a.real_cold_starts, a.logical_cold_starts,
                             a.releases, a.evictions, a.migrations),
                "placement": _placement_state(plat),
                "wall_s": round(time.perf_counter() - t0, 1),
            }
        legacy, pipe = arms[legacy_name], arms[pipeline_name]
        # explicit raises, not asserts: the gate must fire under -O too
        for key in ("density", "qos_violation", "requests",
                    "nodes_peak", "counters", "placement"):
            if legacy[key] != pipe[key]:
                raise RuntimeError(
                    f"pipeline parity: {legacy_name} vs {pipeline_name} "
                    f"diverged on {key}"
                    + ("" if key == "placement" else
                       f" ({legacy[key]} vs {pipe[key]})"))
        rows.append({
            "pair": f"{legacy_name}/{pipeline_name}",
            "target_nodes": target_nodes,
            "density": round(legacy["density"], 3),
            "qos_violation": round(legacy["qos_violation"], 4),
            "decisions": legacy["counters"][0],
            "placed": legacy["counters"][3],
            "wall_legacy_s": legacy["wall_s"],
            "wall_pipeline_s": pipe["wall_s"],
            "parity": True,
        })
        print(f"# pipeline-parity {legacy_name}@{target_nodes}: "
              f"density={rows[-1]['density']} "
              f"placed={rows[-1]['placed']} => identical", flush=True)
    emit(rows)
    return {"kind": kind, "duration_s": duration,
            "n_functions": n_functions, "rows": rows}


# ---------------------------------------------------------------------------
# Cells parity: legacy single-loop Simulation vs the single-cell event core
# ---------------------------------------------------------------------------


def _deterministic_counters(res) -> dict:
    """Every run counter that is deterministic under a fixed ground-truth
    RNG stream.  Wall-clock fields (sched/cold-start latencies) and the
    predictor's cumulative inference counters are excluded by design:
    the former differ between any two runs, the latter accumulate across
    runs sharing one world."""
    s, a = res.sched, res.scaling
    return {
        "requests": res.requests,
        "violated_requests": res.violated_requests,
        "per_fn_violations": dict(res.per_fn_violations),
        "per_fn_requests": dict(res.per_fn_requests),
        "instance_seconds": res.instance_seconds,
        "node_seconds": res.node_seconds,
        "nodes_peak": res.nodes_peak,
        "density_series": list(res.density_series),
        "decisions": s.decisions, "placed": s.instances_placed,
        "fast": s.fast, "slow": s.slow, "failed": s.failed,
        "real_cold": a.real_cold_starts,
        "logical_cold": a.logical_cold_starts,
        "blocked_logical": a.blocked_logical,
        "migrations": a.migrations, "releases": a.releases,
        "evictions": a.evictions,
    }


def cells_parity(kind: str = "burst-storm", duration: int = 120,
                 target_nodes: int = 24, n_functions: int = 8,
                 seed: int = 0,
                 systems=("k8s", "jiagu", "harvesting")) -> dict:
    """The sharded-core reproduction gate: a single-cell
    ``CellSimulation`` (the event-driven loop over the exact legacy
    assembly) must reproduce the legacy ``Simulation`` bit-for-bit —
    density, QoS, and every scheduling/scaling counter.  Both arms run
    against one shared world with the ground-truth RNG re-seeded
    between runs, so any divergence is the event core's fault, not
    noise.  Raises on divergence; ``benchmarks.scaling`` records the
    outcome as the ``cells_parity`` metric in ``BENCH_scaling.json``."""
    from repro.platform import cell_scenario_simulation

    base = {
        "scenario": {"kind": kind, "n_functions": n_functions,
                     "duration_s": duration,
                     "target_nodes": target_nodes, "seed": seed},
        "prediction": {"n_train": 1000, "n_trees": 16},
    }
    scenario = scenario_from_config(PlatformConfig.from_dict(base))
    world = scenario_world(scenario, n_train=1000, n_trees=16)
    rows = []
    for system in systems:
        manifest = copy.deepcopy(base)
        manifest["scheduler"] = {"name": system}
        cfg = PlatformConfig.from_dict(manifest)
        world.gt.reseed()
        legacy = Platform.build(scenario=scenario, config=cfg,
                                world=world).run()
        world.gt.reseed()
        cells = cell_scenario_simulation(scenario, system, n_cells=1,
                                         world=world).run()
        a, b = (_deterministic_counters(legacy),
                _deterministic_counters(cells))
        diverged = sorted(k for k in a if a[k] != b[k])
        if diverged:
            raise RuntimeError(
                f"cells parity: {system} diverged on {diverged}")
        rows.append({"system": system, "decisions": a["decisions"],
                     "placed": a["placed"],
                     "density": round(legacy.density, 3),
                     "qos_violation":
                         round(legacy.qos_violation_rate, 4),
                     "parity": True})
        print(f"# cells-parity {system}@{target_nodes}: "
              f"decisions={a['decisions']} placed={a['placed']} "
              f"=> identical", flush=True)
    return {"kind": kind, "duration_s": duration,
            "target_nodes": target_nodes, "n_functions": n_functions,
            "rows": rows, "parity": True}


# ---------------------------------------------------------------------------
# Router A/B: equal split vs the locality/affinity router
# ---------------------------------------------------------------------------


def router_ab(kind: str = "burst-storm", duration: int = 180,
              target_nodes: int = 128, n_functions: int = 16,
              seed: int = 0) -> dict:
    """A/B the registered routers on the same scenario: the paper's
    equal split vs the ``locality`` router (traffic prefers a
    function's least-contended placements, spilling by score).  Both
    arms build from scratch so they face identical world state; the
    routing policy is the only difference."""
    rows = []
    raw_requests = []
    for router in ("equal-split", "locality"):
        manifest = {
            "scenario": {"kind": kind, "n_functions": n_functions,
                         "duration_s": duration,
                         "target_nodes": target_nodes, "seed": seed},
            "scheduler": {"name": "jiagu"},
            "prediction": {"n_train": 1000, "n_trees": 16},
            "simulation": {"router": router},
        }
        t0 = time.perf_counter()
        _plat, res = _run_manifest(manifest)
        raw_requests.append(res.requests)
        rows.append({
            "router": router, "target_nodes": target_nodes,
            "density": round(res.density, 3),
            "qos_violation": round(res.qos_violation_rate, 4),
            "requests": round(res.requests, 1),
            "real_cold_starts": res.scaling.real_cold_starts,
            "wall_s": round(time.perf_counter() - t0, 1),
        })
        print(f"# router-ab {router}: density={rows[-1]['density']} "
              f"qos={rows[-1]['qos_violation']} "
              f"({rows[-1]['wall_s']}s)", flush=True)
    emit(rows)
    eq_reqs, loc_reqs = raw_requests       # unrounded: the row values
    #                                        are display-rounded
    if abs(eq_reqs - loc_reqs) > 1e-6 * eq_reqs:
        raise RuntimeError(
            f"router-ab: routed request totals diverged "
            f"({eq_reqs} vs {loc_reqs}) — the locality router must "
            f"conserve traffic")
    return {"kind": kind, "duration_s": duration,
            "target_nodes": target_nodes, "rows": rows}


# ---------------------------------------------------------------------------
# Online retraining at scale: --retrain-online
# ---------------------------------------------------------------------------


def retrain_online(quick: bool = False, seed: int = 0,
                   target_nodes: int = 256) -> dict:
    """Online incremental retraining + node-shape-aware capacities,
    exercised at 256 nodes on the heterogeneous topology.

    Runs the same scenario twice through the PredictionService path with
    in-run retraining armed (schema v1, then schema v2) and reports, per
    schema:

      * retrain cost (forest refits) and the retrain-triggered
        capacity-table refresh cost, separately from the
        scheduling-critical-path cost (the paper's core accounting
        split, extended to the retraining loop),
      * the stale-epoch cache-hit counter — asserted **zero**: a
        post-retrain lookup must never see a pre-retrain capacity,
      * density / QoS — schema v2 must strictly increase admitted
        density with a QoS violation rate no worse than v1's (the
        node-shape-aware capacity lift on the mixed std/2x fleet).
    """
    duration = 150 if quick else 420
    n_functions = 12 if quick else 24
    n_train = 1600 if quick else 2600
    n_trees = 16 if quick else 24
    base = {
        "scenario": {"kind": "burst-storm", "n_functions": n_functions,
                     "duration_s": duration,
                     "target_nodes": target_nodes, "seed": seed},
        "scheduler": {"name": "jiagu"},
        "prediction": {"n_train": n_train, "n_trees": n_trees,
                       "max_depth": 10, "online_retrain": True,
                       "retrain_every": 48},
        "simulation": {"collect_samples": True, "sample_every_s": 5},
    }
    scenario = scenario_from_config(PlatformConfig.from_dict(base))
    rows = []
    for version in (1, 2):
        manifest = copy.deepcopy(base)
        manifest["prediction"]["schema_version"] = version
        cfg = PlatformConfig.from_dict(manifest)
        world = scenario_world(scenario, n_train=n_train, n_trees=n_trees,
                               max_depth=10, schema_version=version)
        t0 = time.perf_counter()
        plat = Platform.build(scenario=scenario, config=cfg, world=world)
        res = plat.run()
        wall = time.perf_counter() - t0
        svc = plat.service
        s = res.sched
        row = {
            "schema": f"v{version}", "target_nodes": target_nodes,
            "duration_s": duration, "mean_nodes":
                round(res.node_seconds / max(res.ticks, 1), 1),
            "density": round(res.density, 3),
            "qos_violation": round(res.qos_violation_rate, 4),
            # scheduling-critical-path cost
            "sched_ms_mean": round(s.mean_latency_ms, 4),
            "sched_ms_p99": round(s.p99_latency_ms, 4),
            "critical_rows": s.critical_inference_rows,
            # background: async table updates vs retraining vs refresh
            "async_rows": s.async_inference_rows,
            "retrains": res.retrains,
            "retrain_time_s": round(res.retrain_time_s, 2),
            "refresh_rows": res.refresh_rows,
            "refresh_time_s": round(res.refresh_time_s, 2),
            "stale_epoch_hits": res.stale_epoch_hits,
            "cache_epochs": svc.stats.cache_epochs,
            "wall_s": round(wall, 1),
        }
        rows.append(row)
        print(f"# retrain-online schema v{version}: "
              f"density={row['density']} qos={row['qos_violation']} "
              f"retrains={row['retrains']} "
              f"retrain={row['retrain_time_s']}s "
              f"refresh={row['refresh_time_s']}s "
              f"sched_mean={row['sched_ms_mean']}ms ({row['wall_s']}s)",
              flush=True)
        # explicit raises, not asserts: gates must also fire under -O
        if res.retrains < 1:
            raise RuntimeError("retrain-online: no retrain fired "
                               "(sampling cadence too sparse?)")
        if res.stale_epoch_hits != 0:
            raise RuntimeError(
                f"retrain-online: {res.stale_epoch_hits} stale-epoch "
                f"cache hits served (epoch invalidation broken)")
    emit(rows)
    v1, v2 = rows
    if v2["density"] <= v1["density"]:
        raise RuntimeError(
            f"retrain-online: schema v2 density {v2['density']} did not "
            f"exceed v1's {v1['density']} on the heterogeneous topology")
    if v2["qos_violation"] > v1["qos_violation"] + 1e-9:
        raise RuntimeError(
            f"retrain-online: schema v2 QoS violation "
            f"{v2['qos_violation']} worse than v1's "
            f"{v1['qos_violation']}")
    record = {"target_nodes": target_nodes, "duration_s": duration,
              "n_functions": n_functions, "rows": rows}
    save_artifact("retrain_online", record)
    print(f"# retrain-online: v2/v1 density "
          f"{v2['density'] / max(v1['density'], 1e-9):.3f}x, "
          f"stale_epoch_hits=0 => PASS")
    return record


def _headline_metrics(rows: list) -> dict:
    """Per-system headline scalars for the RunReport: mean density,
    worst QoS violation rate, worst cold-start / sched-cost p99."""
    out = {}
    systems = sorted({r["system"] for r in rows})
    for system in systems:
        rs = [r for r in rows if r["system"] == system]
        out[f"{system}.density_mean"] = round(
            sum(r["density"] for r in rs) / len(rs), 3)
        out[f"{system}.qos_violation_max"] = max(
            r["qos_violation"] for r in rs)
        out[f"{system}.cold_ms_p99_max"] = max(
            r["cold_ms_p99"] for r in rs)
        out[f"{system}.sched_ms_p99_max"] = max(
            r["sched_ms_p99"] for r in rs)
    return out


def run(quick: bool = False, seed: int = 0, spec: dict = None,
        bench: bool = False):
    """``spec`` defaults to ``study_spec(quick, seed)`` —
    ``benchmarks.run`` passes its own so the whole study is driven by
    one manifest tree.  ``bench=True`` (the driver/__main__ path)
    additionally persists a ``RunReport`` into the repo-root
    ``BENCH_large_cluster.json`` trajectory for the regression gate and
    the dashboard; library callers (tests) default to not touching the
    repo root."""
    spec = spec or study_spec(quick=quick, seed=seed)
    rows = run_study(spec)
    print("\n# A/B full-trace parity (legacy vs CapacityEngine)")
    parity = ab_parity(duration=120 if quick else 300, seed=spec["seed"])
    print(f"# parity: tables_equal={parity['tables_equal']} "
          f"density={parity['engine']['density']:.3f} "
          f"qos={parity['engine']['qos_violation']:.4f} => PASS")
    print("\n# pipeline parity (legacy schedule() vs decision pipeline)")
    pipe_parity = pipeline_parity(duration=60 if quick else 150,
                                  seed=spec["seed"])
    print("# pipeline-parity: 4/4 stacks identical => PASS")
    print("\n# router A/B (equal split vs locality)")
    routers = router_ab(duration=120 if quick else 300,
                        target_nodes=64 if quick else 128,
                        seed=spec["seed"])
    bad_qos = [r for r in rows if r["system"] in ("jiagu", "harvesting")
               and r["qos_violation"] >= 0.10]
    if bad_qos:
        print(f"# WARNING: {len(bad_qos)} prediction-backed rows "
              f"at/above the 10% QoS bar: "
              + ", ".join(f"{r['scenario']}@{r['target_nodes']}"
                          f"/{r['system']}" for r in bad_qos))
    record = {"sizes": spec["sizes"], "kinds": list(spec["kinds"]),
              "base_manifest": spec["base"],
              "n_functions": N_FUNCTIONS, "rows": rows,
              "ab_parity": parity, "pipeline_parity": pipe_parity,
              "router_ab": routers}
    save_artifact("large_cluster", record)
    if bench:
        report = RunReport.build(
            "large_cluster", mode="quick" if quick else "full",
            manifest={"sizes": spec["sizes"],
                      "kinds": list(spec["kinds"]),
                      "systems": list(spec.get("systems", STUDY_SYSTEMS)),
                      "base": spec["base"]},
            metrics=_headline_metrics(rows), rows=rows,
            meta={"ab_tables_equal": parity["tables_equal"],
                  "n_functions": N_FUNCTIONS})
        path = append_bench(report)
        print(f"# bench: appended {report.mode} run "
              f"({len(rows)} rows, git {report.git_sha}) -> {path}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 scenario kinds x {64,128} nodes, short traces")
    ap.add_argument("--retrain-online", action="store_true",
                    help="256-node online-retraining + schema v1-vs-v2 "
                         "node-shape capacity-lift study (skips the "
                         "density sweep)")
    ap.add_argument("--cells-parity", action="store_true",
                    help="single-cell event core vs legacy Simulation "
                         "bit-parity gate (skips the density sweep)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.retrain_online:
        retrain_online(quick=args.quick, seed=args.seed)
    elif args.cells_parity:
        cells_parity(seed=args.seed)
        print("# cells-parity: all systems identical => PASS")
    else:
        run(quick=args.quick, seed=args.seed, bench=True)
