"""Cell-sharded event-core scaling study: 1k -> 10k-node fleets at
sub-linear per-node cost.

The legacy ``Simulation`` pays O(nodes) per tick — every spec visited
by the autoscaler, every node visited by ``_measure`` — so a 10k-node
study costs 100x a 100-node one regardless of how much of the fleet is
actually doing anything.  The cell-sharded event core
(``repro.core.cells``) pays only for *due* work: per-cell due sets
(arrivals, drop transitions, wake-heap expiries, dirty marks) gate
scheduling, and dirty-set measurement visits only nodes hosting live
traffic.  This study drives the Azure-like sparse long-tail population
(most functions idle at any instant — the regime the event core is
built for) through the ``repro.platform`` control plane with
``cells.count = 4`` at 1k -> 10k target nodes and reports wall-clock
per node per size.

Gates (recorded in ``BENCH_scaling.json`` and enforced by the
telemetry regression gate):

  * ``wallclock_per_node_slope`` — log-log slope of wall-seconds per
    node vs fleet size must stay **< 1.0** (sub-linear per-node cost:
    total wall-clock grows strictly slower than quadratically, the
    naive all-pairs floor a full-scan loop trends toward as per-tick
    work itself scales with the fleet).
  * ``cells_parity`` — the single-cell event core must reproduce the
    legacy ``Simulation`` bit-for-bit (``large_cluster.cells_parity``,
    also gated in tier-1 by ``tests/test_cells.py``).

  PYTHONPATH=src python -m benchmarks.scaling [--quick | --smoke]

``--smoke`` (the ``scripts/verify.sh --scale`` arm) runs one 1k-node
size plus the parity gate and writes no trajectory.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from .common import emit, save_artifact
from .large_cluster import cells_parity

from repro.core import scenario_world
from repro.platform import Platform, PlatformConfig, scenario_from_config
from repro.telemetry import RunReport, append_bench

KIND = "azure-sparse"
N_CELLS = 4
N_FUNCTIONS = 32
#: per-node wall-clock must grow sub-linearly in fleet size
SLOPE_MAX = 1.0


def study_spec(quick: bool = False, seed: int = 0,
               smoke: bool = False) -> dict:
    sizes = [1000] if smoke else \
        [1000, 4000, 10000] if quick else [1000, 2000, 4000, 10000]
    return {
        "sizes": sizes,
        "seed": seed,
        "base": {
            "scenario": {"kind": KIND, "n_functions": N_FUNCTIONS,
                         "duration_s": 90 if (quick or smoke) else 180,
                         "seed": seed, "spec_seed": seed + 5},
            "prediction": {"n_train": 1500, "n_trees": 16},
            "cells": {"count": N_CELLS},
        },
    }


def _run_size(spec: dict, target: int, world):
    import copy
    manifest = copy.deepcopy(spec["base"])
    manifest["scenario"]["target_nodes"] = target
    cfg = PlatformConfig.from_dict(manifest)
    scenario = scenario_from_config(cfg)
    if world is None:
        world = scenario_world(scenario, n_train=cfg.prediction.n_train,
                               n_trees=cfg.prediction.n_trees)
    t0 = time.perf_counter()
    plat = Platform.build(scenario=scenario, config=cfg, world=world)
    res = plat.run()
    wall = time.perf_counter() - t0
    sim = plat.simulation
    row = {
        "target_nodes": target,
        "cells": N_CELLS,
        "mean_nodes": round(res.node_seconds / max(res.ticks, 1), 1),
        "peak_nodes": res.nodes_peak,
        "density": round(res.density, 3),
        "qos_violation": round(res.qos_violation_rate, 4),
        "decisions": res.sched.decisions,
        "placed": res.sched.instances_placed,
        "idle_cell_frac": round(
            sim.idle_cell_ticks / max(sim.cell_ticks, 1), 3),
        "exchange_published": sim.exchange.published
        if sim.exchange is not None else 0,
        "wall_s": round(wall, 1),
        "wall_ms_per_node": round(wall * 1e3 / target, 4),
    }
    return row, world


def run(quick: bool = False, seed: int = 0, bench: bool = False,
        smoke: bool = False):
    """The 1k -> 10k wall-clock curve.  One function population and one
    trained forest are shared across sizes (only the trace scale and the
    node budget change), so the curve isolates simulation cost.
    ``bench=True`` persists a ``RunReport`` into ``BENCH_scaling.json``
    for the regression gate and the dashboard."""
    spec = study_spec(quick=quick, seed=seed, smoke=smoke)
    rows = []
    world = None
    for target in spec["sizes"]:
        row, world = _run_size(spec, target, world)
        rows.append(row)
        print(f"# scaling {KIND}@{target} x{N_CELLS}cells: "
              f"wall={row['wall_s']}s "
              f"({row['wall_ms_per_node']}ms/node) "
              f"density={row['density']} qos={row['qos_violation']} "
              f"idle={row['idle_cell_frac']}", flush=True)
    emit(rows)

    slope = 0.0
    if len(rows) >= 2:
        ns = [r["target_nodes"] for r in rows]
        per_node = [max(r["wall_s"], 1e-9) / r["target_nodes"]
                    for r in rows]
        slope = float(np.polyfit(np.log(ns), np.log(per_node), 1)[0])
        # explicit raise, not assert: the gate must fire under -O too
        if slope >= SLOPE_MAX:
            raise RuntimeError(
                f"scaling: per-node wall-clock grows super-linearly "
                f"(log-log slope {slope:.3f} >= {SLOPE_MAX})")
        print(f"# per-node wall-clock slope over {ns}: {slope:.3f} "
              f"=> PASS (< {SLOPE_MAX})")

    print("\n# cells parity (single-cell event core vs legacy loop)")
    parity = cells_parity(seed=seed)
    print("# cells-parity: all systems identical => PASS")

    record = {"kind": KIND, "n_cells": N_CELLS,
              "n_functions": N_FUNCTIONS, "sizes": spec["sizes"],
              "base_manifest": spec["base"], "rows": rows,
              "wallclock_per_node_slope": round(slope, 4),
              "cells_parity": parity["parity"]}
    save_artifact("scaling", record)
    if bench:
        report = RunReport.build(
            "scaling", mode="quick" if quick else "full",
            manifest={"kind": KIND, "n_cells": N_CELLS,
                      "sizes": spec["sizes"], "base": spec["base"]},
            metrics={"wallclock_per_node_slope": round(slope, 4),
                     "cells_parity": parity["parity"],
                     "wall_s_max_size": rows[-1]["wall_s"],
                     "qos_violation_max": max(r["qos_violation"]
                                              for r in rows),
                     "idle_cell_frac_min": min(r["idle_cell_frac"]
                                               for r in rows)},
            rows=rows)
        path = append_bench(report)
        print(f"# bench: appended {report.mode} run "
              f"({len(rows)} rows, git {report.git_sha}) -> {path}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="{1k,4k,10k} nodes, 90-tick traces")
    ap.add_argument("--smoke", action="store_true",
                    help="one 1k-node size + the parity gate, no "
                         "trajectory write (scripts/verify.sh --scale)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke, seed=args.seed,
        bench=not args.smoke)
