"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

| paper artifact                          | module            |
|-----------------------------------------|-------------------|
| Fig 11/12 scheduling cost + cold start  | scheduling_cost   |
| Table 2 overhead vs container systems   | scheduling_cost   |
| Fig 13 normalized density               | density           |
| Fig 14 QoS violations + reduced starts  | qos_coldstart     |
| Fig 15/16/17 prediction + model zoo     | prediction        |
| capacity-engine scaling (24->512 nodes) | capacity_engine   |
| large-cluster scenario study + A/B gate | large_cluster     |
| kernel/arch microbench                  | model_perf        |
| §Roofline table (reads dry-run JSONs)   | roofline_report   |
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter traces / fewer repetitions")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (capacity_engine, density, large_cluster, model_perf,
                   prediction, qos_coldstart, roofline_report,
                   scheduling_cost)
    suites = [
        ("scheduling_cost", lambda: scheduling_cost.run(
            duration=300 if args.quick else 600, quick=args.quick)),
        ("density", lambda: density.run(
            duration=300 if args.quick else 600, quick=args.quick)),
        ("qos_coldstart", lambda: qos_coldstart.run(
            duration=300 if args.quick else 600, quick=args.quick)),
        ("prediction", lambda: prediction.run(quick=args.quick)),
        ("capacity_engine", lambda: capacity_engine.run(
            quick=args.quick, bench=True)),
        # the large-cluster study is driven through repro.platform
        # manifests: one PlatformConfig.from_dict-validated dict per
        # (scenario, size, system) run, derived from this spec; each
        # run's observer streams (ticks / schedule decisions with
        # DecisionTrace summaries / scaling / retrains) land in
        # artifacts/events/*.jsonl for cross-run dashboards
        # both studies persist RunReports into the repo-root
        # BENCH_*.json trajectories (repro.telemetry.report) — the
        # regression gate and the dashboard read them
        ("large_cluster", lambda: large_cluster.run(
            quick=args.quick,
            spec=large_cluster.study_spec(quick=args.quick),
            bench=True)),
        ("model_perf", lambda: model_perf.run(quick=args.quick)),
        ("roofline_report", lambda: roofline_report.run()),
    ]
    for name, fn in suites:
        if args.only and name != args.only:
            continue
        print(f"\n{'=' * 70}\n# benchmark: {name}\n{'=' * 70}")
        t0 = time.time()
        fn()
        print(f"# {name} done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
