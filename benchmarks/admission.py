"""Admission study: vertical scaling + queue-backed admission vs the
horizontal-only control plane, on the 256-node burst-storm scenario.

Two arms share scenario, world seed, harvesting scheduler and the SLO
class population (half the functions tagged best-effort); only the
admission axis differs:

  * ``vertical-queue`` — KEDA-style queue-backed scaling signal
    (best-effort arrivals clamp to current service rate plus geometric
    backlog catch-up; latency-critical insta-scales) and the vertical
    resizer harvesting idle cpu reservations through the
    PredictionService capacity tables.
  * ``horizontal-only`` — the same queues meter and account traffic
    (identical per-class QoS bookkeeping) but the autoscaler sees the
    legacy instantaneous rps signal and no instance is ever resized.

Headline metrics, gated in-run and against ``BENCH_admission.json`` by
the telemetry regression gate:

  * ``density_win`` — seed-mean density delta (vertical-queue minus
    horizontal-only) must stay **> 0**: vertical harvest + paced
    scale-out packs denser than storm-chasing horizontal scaling.
  * ``lc_excess`` — the latency-critical violation-rate delta may not
    exceed ``LC_EXCESS_MAX``: the density win cannot be bought by
    queueing the latency-critical class past its budget.
  * ``conservation`` — per-queue request conservation (arrived ==
    released + dropped + pending) at float-eps, every arm, every seed.

  PYTHONPATH=src python -m benchmarks.admission [--quick | --smoke]

``--smoke`` (the ``scripts/verify.sh --admission`` arm) runs one seed
on a 24-node fleet in seconds: the A/B deltas are noise at that scale,
so only the conservation and accounting gates apply.
"""
from __future__ import annotations

import argparse
import time

from .common import emit, save_artifact

from repro.platform import Platform
from repro.telemetry import RunReport, append_bench

KIND = "burst-storm"
N_FUNCTIONS = 24
#: seed-mean latency-critical violation-rate excess allowed for the
#: vertical-queue arm (per-seed deltas are +/-0.005 noise; the mean
#: must stay within this of the horizontal-only baseline)
LC_EXCESS_MAX = 0.0075
#: per-queue conservation residual (absolute requests)
CONSERVATION_MAX = 1e-6

#: the two admission arms (PlatformConfig ``admission:`` sections)
ARMS = {
    "vertical-queue": {"enabled": True, "vertical": True,
                       "signal": "queue", "target_drain_s": 1.0},
    "horizontal-only": {"enabled": True, "signal": "rps"},
}


def study_spec(quick: bool = False, seed: int = 0,
               smoke: bool = False) -> dict:
    if smoke:
        nodes, duration, seeds = 24, 120, [seed]
    elif quick:
        nodes, duration, seeds = 128, 300, [seed, seed + 1, seed + 2]
    else:
        nodes, duration, seeds = 256, 420, [seed, seed + 1, seed + 2]
    return {
        "seeds": seeds,
        "base": {
            "scenario": {"kind": KIND, "n_functions": N_FUNCTIONS,
                         "duration_s": duration, "target_nodes": nodes,
                         "utilization": 1.1, "seed": seed,
                         "trace_kw": {"storms_per_hour": 30.0,
                                      "coherence": 0.8}},
            "scheduler": {"name": "harvesting"},
        },
        "arms": ARMS,
    }


def run_arm(spec: dict, arm: str, seed: int) -> dict:
    """One (arm, seed) run; returns the benchmark row."""
    import copy
    manifest = copy.deepcopy(spec["base"])
    manifest["scenario"]["seed"] = seed
    manifest["admission"] = dict(spec["arms"][arm])
    t0 = time.perf_counter()
    plat = Platform.build(config=manifest)
    res = plat.run()
    adm = plat.simulation.admission
    cls = res.class_violation_rate()
    row = {
        "system": arm,
        "seed": seed,
        "density": round(res.density, 3),
        "qos_violation": round(res.qos_violation_rate, 4),
        "lc_violation": round(cls.get("latency-critical", 0.0), 4),
        "be_violation": round(cls.get("best-effort", 0.0), 4),
        "queue_delay_p99": round(res.queue_delay_s.p99, 3),
        "queue_depth_peak": round(res.queue_depth_peak, 1),
        "dropped": round(res.dropped_requests, 1),
        "vertical_grows": res.vertical_grows,
        "vertical_shrinks": res.vertical_shrinks,
        "conservation": adm.conservation_error(),
        "requests": round(res.requests, 1),
        "nodes_peak": res.nodes_peak,
        "wall_s": round(time.perf_counter() - t0, 1),
    }
    print(f"# {arm} seed={seed}: density={row['density']} "
          f"qos={row['qos_violation']} lc={row['lc_violation']} "
          f"qd_p99={row['queue_delay_p99']}s "
          f"v={row['vertical_grows']}+{row['vertical_shrinks']} "
          f"({row['wall_s']}s)", flush=True)
    return row


def run(quick: bool = False, seed: int = 0, bench: bool = False,
        smoke: bool = False):
    """Both arms over the seed sweep; gate the vertical-queue arm's
    density win and latency-critical safety against horizontal-only.
    ``bench=True`` persists a ``RunReport`` into
    ``BENCH_admission.json`` for the regression gate."""
    spec = study_spec(quick=quick, seed=seed, smoke=smoke)
    rows = [run_arm(spec, arm, s)
            for s in spec["seeds"] for arm in spec["arms"]]
    emit(rows)

    def mean(arm, key):
        vals = [r[key] for r in rows if r["system"] == arm]
        return sum(vals) / len(vals)

    conservation = max(r["conservation"] for r in rows)
    density_win = round(mean("vertical-queue", "density")
                        - mean("horizontal-only", "density"), 4)
    lc_excess = round(mean("vertical-queue", "lc_violation")
                      - mean("horizontal-only", "lc_violation"), 4)
    metrics = {
        "density_win": density_win,
        "lc_excess": lc_excess,
        "queue_delay_p99": round(mean("vertical-queue",
                                      "queue_delay_p99"), 3),
        "dropped_total": round(sum(r["dropped"] for r in rows), 1),
        "conservation": conservation,
        "vertical_shrinks": sum(r["vertical_shrinks"] for r in rows
                                if r["system"] == "vertical-queue"),
    }
    # explicit raises, not asserts: the gates must fire under -O too
    if conservation > CONSERVATION_MAX:
        raise RuntimeError(
            f"admission: queue conservation residual {conservation} "
            f"> {CONSERVATION_MAX} — requests were lost or invented")
    if not smoke:
        # A/B deltas on one 24-node smoke seed are pure noise; the
        # win is only meaningful over the full seed sweep
        if density_win <= 0.0:
            raise RuntimeError(
                f"admission: vertical-queue density win {density_win} "
                f"<= 0 — vertical harvest + queue-paced scaling lost "
                f"the packing advantage")
        if lc_excess > LC_EXCESS_MAX:
            raise RuntimeError(
                f"admission: latency-critical violation excess "
                f"{lc_excess} > {LC_EXCESS_MAX} — the density win is "
                f"being bought with latency-critical queueing")
    print(f"# admission gates: conservation={conservation:.2e} "
          f"(<= {CONSERVATION_MAX})"
          + ("" if smoke else
             f" density_win={density_win} (> 0) "
             f"lc_excess={lc_excess} (<= {LC_EXCESS_MAX})")
          + " => PASS", flush=True)

    record = {"kind": KIND, "spec": spec, "rows": rows,
              "metrics": metrics}
    save_artifact("admission", record)
    if bench:
        report = RunReport.build(
            "admission", mode="quick" if quick else "full",
            manifest={"kind": KIND, "base": spec["base"],
                      "arms": spec["arms"], "seeds": spec["seeds"]},
            metrics=metrics, rows=rows)
        path = append_bench(report)
        print(f"# bench: appended {report.mode} run "
              f"({len(rows)} rows, git {report.git_sha}) -> {path}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="128 nodes / 300s (full: 256 nodes / 420s)")
    ap.add_argument("--smoke", action="store_true",
                    help="one 24-node seed, conservation gates only "
                         "(scripts/verify.sh --admission)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke, seed=args.seed,
        bench=not args.smoke)
